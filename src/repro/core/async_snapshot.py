"""Asynchronous snapshot pipeline: overlap checkpointing with the step
loop (paper §I's "periodic snapshots in the background", made real).

A snapshot moves through three phases:

  capture   (caller thread, blocking, fast) device arrays are copied into
            a *staging slot* — preallocated, reusable host buffers — at a
            step boundary. This is the only stall the train/serve loop
            pays; everything the checkpoint needs (host bytes, structure,
            pruned op-log, job metadata) is frozen here, so the caller may
            mutate its state immediately after ``snapshot()`` returns.
  encode    (single encode thread, ordered) each leaf runs through the
            delta codec (core.delta / kernels.ckpt_codec): int8
            quantization for error-tolerant kinds, XOR against the
            previous snapshot's staging slot when delta chaining is on,
            content-addressed chunking always.
  commit    chunk blobs stream to the backend on a writer pool
            (``put_blob`` fan-out, bounded in-flight bytes); once every
            blob is durable the manifest is committed by the backend's
            fsync+rename protocol. A checkpoint exists iff its manifest
            does — a crash anywhere earlier leaves only invisible garbage
            blobs, never a corrupt "latest".

Double buffering: with chaining off, two slots (one encoding, one free to
capture) give full overlap. With chaining on, the previous snapshot's
slot stays pinned as the XOR base until its successor commits, so a third
slot keeps capture overlapped. If every slot is pinned when ``snapshot()``
is called, backpressure applies: ``"block"`` waits for the pipeline to
drain a slot, ``"skip"`` drops the request (counted in ``stats``) — a
snapshot cadence faster than the storage can absorb degrades to the
storage's rate instead of queueing unboundedly.

Delta chains: every ``delta_base_interval``-th snapshot is a full base;
the ones between store XOR deltas whose manifest records ``base_step``.
``materialize_manifest_chain`` walks base links back to the full base and
re-applies deltas forward. GC keeps the transitive base closure of every
retained manifest, so a kept checkpoint is always restorable.

Sparse (dirty-chunk) capture: with chaining on, capture no longer pays a
full device->host copy of every leaf. Each leaf's previous-snapshot
fingerprints (per-chunk hashes, device-resident on TPU via the
kernels/ckpt_codec Pallas kernels, host segment-sums otherwise) are
compared against the current value; only the chunks whose fingerprint
changed are compacted and transferred. On TPU this is ONE fused Pallas
launch per leaf (``ops.fused_dirty_chunk_capture``: fingerprint,
in-kernel compare against the device-resident baseline, and
running-count compaction into a bounded buffer, all in a single HBM
read of the leaf) followed by ONE blocking device->host hop — vs the
old two-launch path (fingerprint launch, mask sync, gather launch,
payload sync), which remains the fallback when a step dirties more
chunks than the compaction buffer holds. The buffer is sized
adaptively from each leaf's previous dirty count. Immutable jax leaves
that are literally the same Array object as last capture (common for
frozen params and serving weights) are skipped without reading a byte.
The encode thread then XORs only those dirty chunks against the pinned
previous-snapshot host mirror (``encode_leaf_sparse``, manifest format
3) and patches the mirror in place, so exactly one full host copy stays
alive. Capture stall AND encode work scale with the per-step change
rate, not the model size. Snapshots are assumed to be requested from one
caller thread (fingerprint state is advanced at capture time).
"""
from __future__ import annotations

import threading
import time
import weakref
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api.errors import PolicyError, RestoreError, SnapshotError
from repro.core.backends.base import CheckpointBackend
from repro.core import delta as deltamod
from repro.core.oplog import OpLog
from repro.core.split_state import UpperHalf, flatten_with_paths
from repro.kernels.ckpt_codec.ref import FP_CHUNK_BYTES, FP_SEG_BYTES

MANIFEST_FORMAT = 2         # dense manifests (no sparse leaves)
SPARSE_MANIFEST_FORMAT = 3  # at least one dirty-chunk (sparse) leaf

# bound on blob bytes queued to the writer pool per snapshot; keeps the
# encode thread from racing ahead of a slow backend unboundedly
MAX_PENDING_WRITES = 32


# ---------------------------------------------------------------------------
# sparse capture machinery
# ---------------------------------------------------------------------------

_BACKEND: Optional[str] = None


def _backend() -> str:
    global _BACKEND
    if _BACKEND is None:
        try:
            import jax
            _BACKEND = jax.default_backend()
        except Exception:  # pragma: no cover
            _BACKEND = "cpu"
    return _BACKEND


def _tpu_attached() -> bool:
    return _backend() == "tpu"


@dataclass
class _LeafFP:
    """Per-leaf fingerprint state from the last capture: the baseline
    the next capture's dirty detection compares against. ``fp`` stays
    device-resident on TPU (i32 [n_chunks, 2] from the Pallas kernel)
    and is a host uint64 segment-sum array otherwise. ``wref`` is an
    identity token: a jax Array is immutable, so the same object seen
    again means the leaf is byte-identical — skipped without a read."""
    impl: str                 # "tpu" | "host"
    chunk_bytes: int
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    fp: Any
    wref: Optional[weakref.ref] = None
    # chunks dirty at the last capture: sizes the fused kernel's
    # compaction buffer next time (change rates are stable step-to-step)
    last_dirty: Optional[int] = None


@dataclass
class _SparseLeaf:
    """Capture product for one dirty-chunk leaf: the compacted dirty
    payload plus enough geometry for the encode thread to XOR it against
    the previous snapshot's host mirror."""
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    chunk_bytes: int
    n_chunks: int
    dirty_idx: np.ndarray                 # [k] int64
    dirty_bytes: Optional[np.ndarray]     # [k, chunk_bytes] u8, tail padded
    base_step: int


@dataclass
class _SparseCtx:
    """Everything the capture needs for dirty detection this snapshot.
    ``fp`` is the pipeline's fingerprint store — touched only by the
    caller thread (snapshots are caller-serial)."""
    fp: Dict[Tuple[str, str], _LeafFP]
    chain: bool               # will this snapshot be a chain link?
    base_step: Optional[int]
    chunk_bytes: int
    min_bytes: int
    codec_by_kind: Dict[str, str]
    pool: Optional[ThreadPoolExecutor]
    workers: int
    seen: set = field(default_factory=set)

    def eligible(self, v, codec: Optional[str]) -> bool:
        import jax
        if not isinstance(v, (np.ndarray, jax.Array)):
            return False
        if deltamod.codec_applicable(v, codec):
            return False  # lossy-codec leaves never chain (see encode_leaf)
        return v.nbytes >= self.min_bytes

    def _fingerprint(self, v, host_bytes: Optional[np.ndarray]):
        """-> (impl, fp, wref). Reads the leaf exactly once: on device
        through the Pallas kernel when a TPU is attached, else one
        threaded SIMD pass over the host bytes."""
        import jax
        is_jax = isinstance(v, jax.Array)
        if _tpu_attached() and is_jax and len(v.devices()) == 1:
            # single-device leaves only: a sharded array would be
            # replicated by the kernel call — host path handles those
            from repro.kernels.ckpt_codec import ops
            return ("tpu", ops.chunk_fingerprints(v, self.chunk_bytes),
                    weakref.ref(v))
        if host_bytes is None:
            host_bytes = _leaf_bytes(v)
        fp = _fp_host_threaded(host_bytes, self.chunk_bytes,
                               self.pool, self.workers)
        return "host", fp, (weakref.ref(v) if is_jax else None)

    def record(self, name: str, path: str, v,
               host_bytes: Optional[np.ndarray] = None) -> None:
        """Refresh the fingerprint baseline after a dense capture."""
        impl, fp, wref = self._fingerprint(v, host_bytes)
        self.fp[(name, path)] = _LeafFP(
            impl=impl, chunk_bytes=self.chunk_bytes,
            shape=tuple(v.shape), dtype=str(v.dtype), nbytes=v.nbytes,
            fp=fp, wref=wref)

    def prune(self) -> None:
        """Drop baselines for leaves absent from this capture, so a leaf
        that vanishes and later reappears can't match a stale baseline
        against a mirror that no longer holds it."""
        for key in [k for k in self.fp if k not in self.seen]:
            del self.fp[key]


def _leaf_bytes(v) -> np.ndarray:
    import jax
    host = np.asarray(jax.device_get(v))
    return np.ascontiguousarray(host).reshape(-1).view(np.uint8)


# below this leaf size the executor handoff + GIL wakeups cost more
# than the single SIMD reduction pass they would split
_FP_THREAD_MIN_BYTES = 32 << 20


def _fp_host_threaded(buf: np.ndarray, chunk_bytes: int,
                      pool: Optional[ThreadPoolExecutor],
                      workers: int) -> np.ndarray:
    """fingerprint_host fanned out over chunk-aligned ranges — numpy
    releases the GIL inside the reductions, so for leaves large enough
    to amortize the handoff the read pass scales with cores and
    undercuts the full copy the dense path would pay."""
    from repro.kernels.ckpt_codec.ref import fingerprint_host
    n = buf.nbytes
    if pool is None or workers <= 1 or n < _FP_THREAD_MIN_BYTES:
        return fingerprint_host(buf, chunk_bytes)
    n_chunks = -(-n // chunk_bytes)
    per = -(-n_chunks // workers) * chunk_bytes
    ranges = [(lo, min(n, lo + per)) for lo in range(0, n, per)]
    parts = pool.map(
        lambda r: fingerprint_host(buf[r[0]:r[1]], chunk_bytes), ranges)
    return np.vstack(list(parts))


class _StagingSlot:
    """Reusable pinned host buffers for one in-flight snapshot."""

    def __init__(self) -> None:
        self.buffers: Dict[str, Dict[str, np.ndarray]] = {}
        self.busy = False

    def capture(self, upper: UpperHalf, ctx: Optional[_SparseCtx] = None,
                ) -> Tuple[Dict[str, Dict[str, Any]], Dict[str, int]]:
        """Copy-on-snapshot: device→host. On a real accelerator,
        ``device_get`` already materializes a fresh private host buffer —
        storing it directly avoids a second full memcpy on the only
        stall the caller pays. Host-resident leaves (numpy arrays,
        scalars — and everything on the CPU backend, where ``device_get``
        may alias a donatable buffer) are copied into this slot's
        preallocated pool instead.

        With a sparse context, eligible leaves take the dirty-chunk
        path instead of a full copy (``_try_sparse``); everything else
        falls through to the dense copy and refreshes its fingerprint
        baseline for the next capture."""
        import jax
        accel = jax.default_backend() != "cpu"
        out: Dict[str, Dict[str, Any]] = {}
        st = {"capture_bytes": 0, "dirty_chunks": 0, "clean_chunks": 0,
              "identity_skips": 0, "sparse_leaves": 0}
        for name, e in upper.items():
            pool = self.buffers.setdefault(name, {})
            taken: Dict[str, Any] = {}
            codec = ctx.codec_by_kind.get(e.kind) if ctx else None
            for path, v in flatten_with_paths(e.tree):
                elig = ctx is not None and ctx.eligible(v, codec)
                if elig:
                    ctx.seen.add((name, path))
                    sp = self._try_sparse(name, path, v, ctx, st)
                    if sp is not None:
                        taken[path] = sp
                        continue
                a = np.asarray(jax.device_get(v))
                if not (accel and a is not v
                        and not isinstance(v, np.ndarray)):
                    # not already a private copy: stage into this slot's
                    # preallocated pool
                    buf = pool.get(path)
                    if buf is None or buf.shape != a.shape \
                            or buf.dtype != a.dtype:
                        buf = np.empty(a.shape, a.dtype)
                        pool[path] = buf
                    np.copyto(buf, a)
                    a = buf
                taken[path] = a
                st["capture_bytes"] += a.nbytes
                if elig:
                    # fingerprint the *staged* copy: for an in-place-
                    # mutated numpy leaf only the staged bytes are
                    # guaranteed to be this snapshot's
                    ctx.record(name, path, v,
                               host_bytes=np.ascontiguousarray(a)
                               .reshape(-1).view(np.uint8))
            out[name] = taken
        if ctx is not None:
            ctx.prune()
        return out, st

    def _try_sparse(self, name: str, path: str, v, ctx: _SparseCtx,
                    st: Dict[str, int]) -> Optional[_SparseLeaf]:
        """Dirty-chunk capture for one leaf; None -> take the dense path
        (no baseline yet, geometry changed, or not a chain snapshot)."""
        fpe = ctx.fp.get((name, path))
        if (not ctx.chain or fpe is None
                or fpe.chunk_bytes != ctx.chunk_bytes
                or fpe.shape != tuple(v.shape) or fpe.dtype != str(v.dtype)):
            return None
        cb = ctx.chunk_bytes
        n_chunks = -(-v.nbytes // cb)
        common = dict(shape=tuple(v.shape), dtype=str(v.dtype),
                      nbytes=v.nbytes, chunk_bytes=cb, n_chunks=n_chunks,
                      base_step=ctx.base_step)
        if fpe.wref is not None and fpe.wref() is v:
            # same immutable Array object -> byte-identical, zero reads
            st["identity_skips"] += 1
            st["sparse_leaves"] += 1
            st["clean_chunks"] += n_chunks
            return _SparseLeaf(dirty_idx=np.empty(0, np.int64),
                               dirty_bytes=None, **common)
        import jax
        if fpe.impl == "tpu" and _tpu_attached() \
                and isinstance(v, jax.Array) and len(v.devices()) == 1:
            from repro.kernels.ckpt_codec import ops
            # fused single pass: 1 kernel launch + 1 blocking D2H (the
            # two-launch gather path is its internal overflow fallback)
            fp_new, idx, compact = ops.fused_dirty_chunk_capture(
                v, fpe.fp, cb, capacity_hint=fpe.last_dirty)
            wref = weakref.ref(v)
        elif fpe.impl == "host":
            buf = _leaf_bytes(v)
            fp_new = _fp_host_threaded(buf, cb, ctx.pool, ctx.workers)
            idx = np.nonzero(np.any(fp_new != fpe.fp, axis=1))[0]
            compact = None
            if idx.size:
                # one sliced gather for every full chunk (idx is sorted,
                # so the split point is a searchsorted); only a partial
                # tail chunk — at most one, the last index — is copied
                # scalar and zero-padded
                compact = np.empty((idx.size, cb), np.uint8)
                n_full = buf.size // cb
                k_full = int(np.searchsorted(idx, n_full))
                np.take(buf[:n_full * cb].reshape(n_full, cb),
                        idx[:k_full], axis=0, out=compact[:k_full])
                for j in range(k_full, idx.size):
                    off = int(idx[j]) * cb
                    ln = buf.size - off
                    compact[j, :ln] = buf[off:]
                    compact[j, ln:] = 0
            wref = weakref.ref(v) if isinstance(v, jax.Array) else None
        else:
            return None  # baseline impl doesn't match this leaf anymore
        ctx.fp[(name, path)] = _LeafFP(
            impl=fpe.impl, chunk_bytes=cb, shape=tuple(v.shape),
            dtype=str(v.dtype), nbytes=v.nbytes, fp=fp_new, wref=wref,
            last_dirty=int(idx.size))
        st["sparse_leaves"] += 1
        st["dirty_chunks"] += int(idx.size)
        st["clean_chunks"] += n_chunks - int(idx.size)
        st["capture_bytes"] += int(idx.size) * cb
        return _SparseLeaf(dirty_idx=np.asarray(idx, np.int64),
                           dirty_bytes=compact, **common)


@dataclass
class _Captured:
    """Everything frozen at the capture point."""
    step: int
    slot: _StagingSlot
    host_state: Dict[str, Dict[str, np.ndarray]]
    structure: Dict[str, Any]
    kinds: Dict[str, str]
    log_json: Any
    job_meta: Dict[str, Any]
    capture_seconds: float
    sparse_committed: bool = False  # set by encode: mirror was patched


class SnapshotHandle:
    """Caller's view of one snapshot moving through the pipeline."""

    def __init__(self, step: int) -> None:
        self.step = step
        self._future: Future = Future()
        self.timings: Dict[str, float] = {}

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        """Block until committed; returns the manifest. Raises the
        builtin ``TimeoutError`` when the encode thread hasn't committed
        within ``timeout`` — never a partial result. (On Python < 3.11
        ``concurrent.futures.TimeoutError`` is a distinct type that a
        caller's ``except TimeoutError`` would silently miss.)"""
        try:
            return self._future.result(timeout)
        except _FuturesTimeout:
            if self._future.done():
                # the snapshot itself failed with a TimeoutError (e.g. a
                # storage timeout) — that is the real cause, not us
                raise
            raise TimeoutError(
                f"snapshot for step {self.step} not committed within "
                f"{timeout}s") from None

    # Future-compatible alias so legacy callers treating save()'s return
    # value as a concurrent.futures.Future keep working
    def add_done_callback(self, fn) -> None:
        self._future.add_done_callback(fn)


class AsyncSnapshotter:
    """The capture/encode/commit pipeline (see module docstring)."""

    def __init__(
        self,
        backend: CheckpointBackend,
        *,
        codec_by_kind: Optional[Dict[str, str]] = None,
        delta_base_interval: int = 1,
        backpressure: str = "block",
        writers: int = 4,
        compress: bool = True,
        keep_last: Optional[int] = None,
        prune_oplog: bool = True,
        depth: Optional[int] = None,
        sparse_capture: bool = True,
        sparse_chunk_bytes: int = FP_CHUNK_BYTES,
        sparse_min_bytes: Optional[int] = None,
    ) -> None:
        assert backpressure in ("block", "skip"), backpressure
        assert delta_base_interval >= 1
        self.backend = backend
        self.codec_by_kind = codec_by_kind or {}
        self.delta_base_interval = delta_base_interval
        self.backpressure = backpressure
        self.compress = compress
        self.keep_last = keep_last
        self.prune_oplog = prune_oplog
        if depth is None:  # +1 slot to keep capture overlapped while the
            depth = 2 if delta_base_interval == 1 else 3  # base is pinned
        self._slots = [_StagingSlot() for _ in range(depth)]
        self._cond = threading.Condition()
        self._encode_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="snap-encode")  # ordered
        self._writer_pool = ThreadPoolExecutor(
            max_workers=writers, thread_name_prefix="snap-write")
        self._inflight: List[SnapshotHandle] = []
        self._last_error: Optional[BaseException] = None
        # previous snapshot kept as the XOR base: (step, host_state, slot)
        self._prev: Optional[Tuple[int, Dict[str, Dict[str, np.ndarray]],
                                   _StagingSlot]] = None
        self._chain_len = 0
        # dirty-chunk capture state (caller thread; see module docstring)
        self.sparse_capture = sparse_capture and delta_base_interval > 1
        if self.sparse_capture:
            cb = sparse_chunk_bytes
            # TPU kernel needs whole i32 lane rows (4*BLOCK); the host
            # fingerprint needs chunks to be whole segments — fail at
            # construction, not deep inside the first chained save
            if cb <= 0 or cb % 1024 or (cb > FP_SEG_BYTES
                                        and cb % FP_SEG_BYTES):
                raise PolicyError(
                    f"sparse_chunk_bytes={cb} must be a positive multiple "
                    f"of 1024, and of {FP_SEG_BYTES} once above it")
            # dirty detection pays off where the fingerprint pass avoids
            # moving the data (TPU kernel) or where there is no transfer
            # at all (CPU); on other accelerators the host fingerprint
            # would itself pull every byte off-device — worse than dense
            if _backend() not in ("cpu", "tpu"):
                self.sparse_capture = False
        self.sparse_chunk_bytes = sparse_chunk_bytes
        self.sparse_min_bytes = (sparse_min_bytes if sparse_min_bytes
                                 is not None else 2 * sparse_chunk_bytes)
        self._fp: Dict[Tuple[str, str], _LeafFP] = {}
        self._fp_step: Optional[int] = None
        self._cap_chain_len = 0
        self._fp_invalid = False          # set by encode-thread failures
        self._fp_pool: Optional[ThreadPoolExecutor] = None
        self._fp_workers = 1
        if self.sparse_capture:
            import os
            self._fp_workers = min(4, os.cpu_count() or 1)
            if self._fp_workers > 1:
                self._fp_pool = ThreadPoolExecutor(
                    max_workers=self._fp_workers,
                    thread_name_prefix="snap-fp")
        self.stats: Dict[str, Any] = {
            "saves": 0, "skipped": 0, "failed": 0, "chain_links": 0,
            "bytes_written": 0, "bytes_logical": 0, "bytes_encoded": 0,
            "capture_bytes": 0, "sparse_leaves": 0, "identity_skips": 0,
            "dirty_chunks": 0, "clean_chunks": 0,
            "save_seconds": 0.0, "capture_seconds": 0.0,
            "encode_commit_seconds": 0.0,
        }

    # --- capture (caller thread) ------------------------------------------

    def _acquire_slot(self, must_take: bool = False
                      ) -> Optional[_StagingSlot]:
        with self._cond:
            while True:
                for s in self._slots:
                    if not s.busy:
                        s.busy = True
                        return s
                if self.backpressure == "skip" and not must_take:
                    return None
                self._cond.wait()

    def _release_slot(self, slot: _StagingSlot) -> None:
        with self._cond:
            slot.busy = False
            self._cond.notify_all()

    def snapshot(self, step: int, upper: UpperHalf, oplog: OpLog,
                 job_meta: Optional[Dict[str, Any]] = None,
                 must_take: bool = False) -> Optional[SnapshotHandle]:
        """Capture now; encode + commit in the background. Returns None
        iff the pipeline is saturated and backpressure policy is "skip".
        ``must_take`` overrides a "skip" policy (a caller that asked to
        block has said it will wait — dropping would lose e.g. the final
        checkpoint of a run)."""
        slot = self._acquire_slot(must_take=must_take)
        if slot is None:
            self.stats["skipped"] += 1
            return None
        ctx: Optional[_SparseCtx] = None
        if self.sparse_capture:
            with self._cond:
                if self._fp_invalid:  # an encode failure broke the chain
                    self._fp.clear()
                    self._fp_step = None
                    self._cap_chain_len = 0
                    self._fp_invalid = False
            ctx = _SparseCtx(
                fp=self._fp,
                chain=(self._fp_step is not None and
                       self._cap_chain_len < self.delta_base_interval - 1),
                base_step=self._fp_step,
                chunk_bytes=self.sparse_chunk_bytes,
                min_bytes=self.sparse_min_bytes,
                codec_by_kind=self.codec_by_kind,
                pool=self._fp_pool,
                workers=self._fp_workers,
            )
        t0 = time.monotonic()
        try:
            host_state, cap_st = slot.capture(upper, ctx)
            cap = _Captured(
                step=step,
                slot=slot,
                host_state=host_state,
                structure=upper.structure(),
                kinds={name: e.kind for name, e in upper.items()},
                log_json=(oplog.prune() if self.prune_oplog
                          else oplog).to_json(),
                job_meta=job_meta or {},
                capture_seconds=time.monotonic() - t0,
            )
        except BaseException:
            if ctx is not None:
                # a partial capture may have advanced some leaves'
                # baselines: comparing against them next time would
                # silently mark truly-changed chunks clean
                self._fp.clear()
                self._fp_step = None
                self._cap_chain_len = 0
            self._release_slot(slot)
            raise
        if ctx is not None:
            self._cap_chain_len = self._cap_chain_len + 1 if ctx.chain else 0
            self._fp_step = step
        handle = SnapshotHandle(step)
        handle.timings["capture"] = cap.capture_seconds
        self.stats["capture_seconds"] += cap.capture_seconds
        for k, n in cap_st.items():
            self.stats[k] += n
        with self._cond:
            self._inflight.append(handle)
        self._encode_pool.submit(self._encode_and_commit, cap, handle)
        return handle

    # --- encode + commit (pipeline threads) -------------------------------

    def _encode_and_commit(self, cap: _Captured,
                           handle: SnapshotHandle) -> None:
        t0 = time.monotonic()
        try:
            manifest = self._do_encode_commit(cap)
        except BaseException as e:
            with self._cond:
                self._last_error = e   # drain() re-raises even if the
                self.stats["failed"] += 1  # handle is retired by then
                # the chain base (and possibly a half-patched mirror) is
                # gone; the next capture must re-baseline and the next
                # snapshot will be a full base
                self._fp_invalid = True
            self._retire(cap.slot, handle, keep_as_prev=False)
            handle._future.set_exception(e)
            return
        dt = time.monotonic() - t0
        handle.timings["encode_commit"] = dt
        self.stats["saves"] += 1
        self.stats["encode_commit_seconds"] += dt
        self.stats["save_seconds"] += cap.capture_seconds + dt
        self._retire(cap.slot, handle,
                     keep_as_prev=self.delta_base_interval > 1,
                     step=cap.step, host_state=cap.host_state,
                     reuse_prev=getattr(cap, "sparse_committed", False))
        handle._future.set_result(manifest)

    def _do_encode_commit(self, cap: _Captured) -> Dict[str, Any]:
        chain = (self.delta_base_interval > 1 and self._prev is not None
                 and self._chain_len < self.delta_base_interval - 1)
        base_step = self._prev[0] if chain else None
        base_state = self._prev[1] if chain else {}

        has_sparse = any(isinstance(x, _SparseLeaf)
                         for leaves in cap.host_state.values()
                         for x in leaves.values())
        if has_sparse and not chain:
            # capture predicted a chain link that encode can't honor
            # (the previous snapshot failed after this capture ran);
            # the sparse payload alone can't produce a full base
            raise SnapshotError(
                "sparse capture lost its chain base (a preceding "
                "snapshot failed); this snapshot cannot be encoded")

        writer = _BlobWriter(self.backend, self._writer_pool)
        entries_manifest: Dict[str, Any] = {}
        written = logical = encoded = 0
        for name, leaves in cap.host_state.items():
            codec = self.codec_by_kind.get(cap.kinds[name])
            leaf_metas: Dict[str, Any] = {}
            for path, arr in leaves.items():
                if isinstance(arr, _SparseLeaf):
                    if arr.base_step != base_step:
                        raise SnapshotError(
                            f"sparse capture of {name}:{path} is relative "
                            f"to step {arr.base_step}, but the encode "
                            f"chain base is {base_step}")
                    prev_arr = base_state.get(name, {}).get(path)
                    if prev_arr is None:
                        raise SnapshotError(
                            f"sparse capture of {name}:{path} has no "
                            "previous value in the pinned mirror")
                    m = deltamod.encode_leaf_sparse(
                        arr.shape, arr.dtype, arr.chunk_bytes,
                        arr.n_chunks, arr.dirty_idx,
                        arr.dirty_bytes if arr.dirty_bytes is not None
                        else np.empty((0, arr.chunk_bytes), np.uint8),
                        prev_arr, writer.put, writer.has,
                        compress=self.compress)
                    logical += arr.nbytes
                else:
                    prev_arr = None
                    if chain and not deltamod.codec_applicable(arr, codec):
                        prev_arr = base_state.get(name, {}).get(path)
                    m = deltamod.encode_leaf(
                        arr, writer.put, writer.has,
                        codec=codec, prev=prev_arr, compress=self.compress)
                    logical += arr.nbytes
                    if has_sparse:
                        # the old prev slot stays pinned as the mirror;
                        # fold this dense leaf's bytes into it so the
                        # mirror is the complete current snapshot (the
                        # staged copy belongs to a slot about to be
                        # freed, so take a private copy)
                        mirror = base_state.setdefault(name, {})
                        old = mirror.get(path)
                        if old is not None and old.shape == arr.shape \
                                and old.dtype == arr.dtype:
                            np.copyto(old, arr)
                        else:
                            mirror[path] = np.array(arr)
                written += m.pop("bytes_written", 0)
                encoded += m.pop("bytes_encoded", 0)
                leaf_metas[path] = m
            entries_manifest[name] = {"kind": cap.kinds[name],
                                      "leaves": leaf_metas}
        if has_sparse:
            # leaves absent from this snapshot must leave the mirror too
            for name in list(base_state):
                cur = cap.host_state.get(name)
                if cur is None:
                    del base_state[name]
                    continue
                for path in [p for p in base_state[name] if p not in cur]:
                    del base_state[name][path]
        writer.drain()  # every blob durable before the manifest commits
        manifest = {
            "format": (SPARSE_MANIFEST_FORMAT if has_sparse
                       else MANIFEST_FORMAT),
            "step": cap.step,
            "base_step": base_step,
            "entries": entries_manifest,
            "oplog": cap.log_json,
            "structure": cap.structure,
            "job": cap.job_meta,
        }
        cap.sparse_committed = has_sparse
        self.backend.commit_manifest(cap.step, manifest)
        self._chain_len = self._chain_len + 1 if chain else 0
        if chain:
            self.stats["chain_links"] += 1
        self.stats["bytes_written"] += written
        self.stats["bytes_logical"] += logical
        self.stats["bytes_encoded"] += encoded
        if self.keep_last is not None:
            try:
                self.gc(self.keep_last)
            except Exception:  # noqa: BLE001 — snapshot IS committed;
                # a transient retention failure must not report it lost
                self.stats["gc_failures"] = \
                    self.stats.get("gc_failures", 0) + 1
        return manifest

    def _retire(self, slot: _StagingSlot, handle: SnapshotHandle,
                keep_as_prev: bool, step: int = -1,
                host_state=None, reuse_prev: bool = False) -> None:
        """Slot bookkeeping after a snapshot leaves the pipeline: the
        committed slot becomes the next XOR base (when chaining); the
        base it replaced is freed. A sparse commit (``reuse_prev``)
        instead advanced the pinned mirror in place — the old prev slot
        *stays* prev (now holding this snapshot's bytes) and the capture
        slot's spent dirty payload is freed. The handle's result is set
        by the caller right after — anyone blocked on it wakes with the
        slots already released."""
        with self._cond:
            old_prev = self._prev
            if reuse_prev:
                assert old_prev is not None  # encode validated the base
                self._prev = (step, old_prev[1], old_prev[2])
                slot.busy = False
            else:
                if keep_as_prev:
                    self._prev = (step, host_state, slot)
                else:
                    self._prev = None
                    slot.busy = False
                if old_prev is not None and old_prev[2] is not slot:
                    old_prev[2].busy = False
            self._inflight = [h for h in self._inflight if h is not handle]
            self._cond.notify_all()

    # --- drain / shutdown --------------------------------------------------

    def drain(self) -> None:
        """Block until every in-flight snapshot committed (or failed),
        then re-raise the most recent failure since the last drain —
        including one that completed before drain was called, so
        fire-and-forget callers (snapshot(); ...; wait()) cannot
        silently lose checkpoints."""
        with self._cond:
            pending = list(self._inflight)
        for h in pending:
            try:
                h.result()
            except BaseException:  # noqa: BLE001 — raised via _last_error
                pass
        with self._cond:
            err, self._last_error = self._last_error, None
        if err is not None:
            raise err

    def consume_error(self, err: BaseException) -> None:
        """A caller that already received `err` from a handle (blocking
        save) takes ownership of it, so a later unrelated drain() does
        not re-raise a failure that was handled and possibly retried."""
        with self._cond:
            if self._last_error is err:
                self._last_error = None

    def close(self) -> None:
        try:
            self.drain()
        finally:
            self._encode_pool.shutdown(wait=True)
            self._writer_pool.shutdown(wait=True)
            if self._fp_pool is not None:
                self._fp_pool.shutdown(wait=True)

    # --- gc ----------------------------------------------------------------

    def gc(self, keep_last: int) -> None:
        """Drop all but the last `keep_last` checkpoints — plus the
        transitive base closure of the kept ones, so every survivor's
        delta chain stays restorable — then GC unreferenced blobs."""
        steps = self.backend.list_steps()
        have = set(steps)
        # keep_last <= 0 means "no retention limit", never "drop all"
        keep = set(steps[-keep_last:]) if keep_last > 0 else set(steps)
        frontier = list(keep)
        manifests: Dict[int, Dict[str, Any]] = {}
        while frontier:
            s = frontier.pop()
            m = manifests.get(s) or self.backend.get_manifest(s)
            manifests[s] = m
            b = m.get("base_step")
            if b is not None and b in have and b not in keep:
                keep.add(b)
                frontier.append(b)
        for s in steps:
            if s not in keep:
                self.backend.delete_step(s)
        referenced: set = set()
        for s in keep:
            referenced |= deltamod.referenced_hashes(manifests[s])
        self.backend.gc_blobs(referenced)


class _BlobWriter:
    """Fans blob writes out to the writer pool with a bounded in-flight
    window; drain() rejoins before the manifest commit.

    ``has`` answers "is this blob durable or already queued by me" —
    the backend alone can't, because a queued write hasn't landed yet,
    and asking it directly would re-write (and re-count) every repeated
    chunk within one snapshot (e.g. zero-initialized weights)."""

    def __init__(self, backend: CheckpointBackend,
                 pool: ThreadPoolExecutor,
                 max_pending: int = MAX_PENDING_WRITES) -> None:
        self._backend = backend
        self._pool = pool
        self._sem = threading.Semaphore(max_pending)
        self._futures: List[Future] = []
        self._queued: set = set()  # touched only by the encode thread

    def has(self, name: str) -> bool:
        return name in self._queued or self._backend.has_blob(name)

    def put(self, name: str, data: bytes) -> None:
        self._queued.add(name)
        self._sem.acquire()
        self._futures.append(self._pool.submit(self._write, name, data))

    def _write(self, name: str, data: bytes) -> None:
        try:
            self._backend.put_blob(name, data)
        finally:
            self._sem.release()

    def drain(self) -> None:
        for f in self._futures:
            f.result()
        self._futures.clear()


# ---------------------------------------------------------------------------
# restore side: delta chain -> full state
# ---------------------------------------------------------------------------

# manifest formats this build can decode (1: whole-tree, 2: delta chain,
# 3: sparse dirty-chunk links); a newer format means a newer build wrote
# the checkpoint and silently misreading it would be worse than failing
KNOWN_MANIFEST_FORMATS = (1, 2, 3)


def check_manifest_format(manifest: Dict[str, Any]) -> None:
    fmt = manifest.get("format", 1)
    if fmt not in KNOWN_MANIFEST_FORMATS:
        raise RestoreError(
            f"checkpoint manifest format {fmt} is newer than this build "
            f"understands (known: {KNOWN_MANIFEST_FORMATS})")


def manifest_chain_steps(backend: CheckpointBackend, step: int) -> List[int]:
    """base-first list of steps whose manifests `step` depends on."""
    chain = []
    s: Optional[int] = step
    while s is not None:
        m = backend.get_manifest(s)
        check_manifest_format(m)
        chain.append(s)
        s = m.get("base_step")
    chain.reverse()
    return chain


def leaf_chain_start(manifests: List[Dict[str, Any]], name: str,
                     path: str) -> int:
    """Index of the manifest where ``(name, path)``'s decode run starts:
    walk base links back only as far as its run of xor modes reaches (a
    full or codec leaf needs no predecessor). An entry or leaf first
    introduced mid-chain bounds the walk — the predecessor manifest
    simply doesn't carry it — so the run starts at the introduction
    instead of raising KeyError. Every manifest in ``[start:]`` is
    guaranteed to carry the leaf; this is the single definition of a
    leaf's chain shared by the eager decoder and the streaming planner
    (which is what makes their blob plans identical by construction)."""
    i = len(manifests) - 1
    while i > 0 and (manifests[i]["entries"][name]["leaves"][path]
                     .get("mode") == "xor"):
        prev = manifests[i - 1]["entries"].get(name, {}) \
            .get("leaves", {}).get(path)
        if prev is None:
            break   # first introduced here: nothing earlier to walk to
        i -= 1  # xor decodes against the predecessor's value
    return i


def _decode_chain_leaf(manifests: List[Dict[str, Any]], backend,
                       name: str, path: str) -> np.ndarray:
    """Decode one leaf of the final manifest from the start of its xor
    run (``leaf_chain_start``) forward, XOR-applying each link."""
    i = leaf_chain_start(manifests, name, path)
    val: Optional[np.ndarray] = None
    for m in manifests[i:]:
        val = deltamod.decode_leaf(
            m["entries"][name]["leaves"][path], backend.get_blob, prev=val)
    return val


# below this leaf count a worker pool costs more than it hides; tiny
# checkpoints (scalars + a couple of tensors) decode inline
_PARALLEL_DECODE_MIN_LEAVES = 4


def materialize_manifest_chain(
    backend: CheckpointBackend, step: int, workers: Optional[int] = None,
    skip_entries=(),
) -> Tuple[Dict[str, Any], Dict[str, Dict[str, np.ndarray]]]:
    """Delta chain -> full state. Each leaf decodes independently (its
    own xor-run walk), so leaves fan out across a worker pool — restore
    latency is bounded by the largest leaf's chain, not the sum of all
    of them. Leaves that exist only in intermediate manifests — or are
    non-xor there — are never decoded, so restore cost per leaf stays
    O(xor-run length), not O(chain length). Sparse (format-3) links
    apply as copy + dirty-chunk patch rather than a full-buffer XOR, so
    chain application also scales with what each link changed.

    ``workers``: decode pool size; default scales with the host, 1
    forces the serial path (both orders produce identical arrays).
    ``skip_entries``: entry names to leave undecoded (absent from the
    result) — a caller that rebuilds an entry from scratch, like the
    serving engine re-slotting its KV cache, shouldn't pay its chain."""
    manifests = [backend.get_manifest(s)
                 for s in manifest_chain_steps(backend, step)]
    final = manifests[-1]
    skip = set(skip_entries)
    tasks = [(name, path) for name, e in final["entries"].items()
             if name not in skip for path in e["leaves"]]
    if workers is None:
        import os
        workers = min(8, os.cpu_count() or 1)
    if workers > 1 and len(tasks) >= _PARALLEL_DECODE_MIN_LEAVES:
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="chain-decode") as pool:
            vals = list(pool.map(
                lambda t: _decode_chain_leaf(manifests, backend, *t), tasks))
    else:
        vals = [_decode_chain_leaf(manifests, backend, name, path)
                for name, path in tasks]
    entries: Dict[str, Dict[str, np.ndarray]] = {}
    for (name, path), val in zip(tasks, vals):
        entries.setdefault(name, {})[path] = val
    # entries present in the manifest but empty of leaves (e.g. an empty
    # request queue) must still appear in the restored state
    for name in final["entries"]:
        if name not in skip:
            entries.setdefault(name, {})
    return final, entries
