"""Asynchronous snapshot pipeline: overlap checkpointing with the step
loop (paper §I's "periodic snapshots in the background", made real).

A snapshot moves through three phases:

  capture   (caller thread, blocking, fast) device arrays are copied into
            a *staging slot* — preallocated, reusable host buffers — at a
            step boundary. This is the only stall the train/serve loop
            pays; everything the checkpoint needs (host bytes, structure,
            pruned op-log, job metadata) is frozen here, so the caller may
            mutate its state immediately after ``snapshot()`` returns.
  encode    (single encode thread, ordered) each leaf runs through the
            delta codec (core.delta / kernels.ckpt_codec): int8
            quantization for error-tolerant kinds, XOR against the
            previous snapshot's staging slot when delta chaining is on,
            content-addressed chunking always.
  commit    chunk blobs stream to the backend on a writer pool
            (``put_blob`` fan-out, bounded in-flight bytes); once every
            blob is durable the manifest is committed by the backend's
            fsync+rename protocol. A checkpoint exists iff its manifest
            does — a crash anywhere earlier leaves only invisible garbage
            blobs, never a corrupt "latest".

Double buffering: with chaining off, two slots (one encoding, one free to
capture) give full overlap. With chaining on, the previous snapshot's
slot stays pinned as the XOR base until its successor commits, so a third
slot keeps capture overlapped. If every slot is pinned when ``snapshot()``
is called, backpressure applies: ``"block"`` waits for the pipeline to
drain a slot, ``"skip"`` drops the request (counted in ``stats``) — a
snapshot cadence faster than the storage can absorb degrades to the
storage's rate instead of queueing unboundedly.

Delta chains: every ``delta_base_interval``-th snapshot is a full base;
the ones between store XOR deltas whose manifest records ``base_step``.
``materialize_manifest_chain`` walks base links back to the full base and
re-applies deltas forward. GC keeps the transitive base closure of every
retained manifest, so a kept checkpoint is always restorable.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.backends.base import CheckpointBackend
from repro.core import delta as deltamod
from repro.core.oplog import OpLog
from repro.core.split_state import UpperHalf, flatten_with_paths

MANIFEST_FORMAT = 2

# bound on blob bytes queued to the writer pool per snapshot; keeps the
# encode thread from racing ahead of a slow backend unboundedly
MAX_PENDING_WRITES = 32


class _StagingSlot:
    """Reusable pinned host buffers for one in-flight snapshot."""

    def __init__(self) -> None:
        self.buffers: Dict[str, Dict[str, np.ndarray]] = {}
        self.busy = False

    def capture(self, upper: UpperHalf) -> Dict[str, Dict[str, np.ndarray]]:
        """Copy-on-snapshot: device→host. On a real accelerator,
        ``device_get`` already materializes a fresh private host buffer —
        storing it directly avoids a second full memcpy on the only
        stall the caller pays. Host-resident leaves (numpy arrays,
        scalars — and everything on the CPU backend, where ``device_get``
        may alias a donatable buffer) are copied into this slot's
        preallocated pool instead."""
        import jax
        accel = jax.default_backend() != "cpu"
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for name, e in upper.items():
            pool = self.buffers.setdefault(name, {})
            taken: Dict[str, np.ndarray] = {}
            for path, v in flatten_with_paths(e.tree):
                host = jax.device_get(v)
                if accel and host is not v and not isinstance(v, np.ndarray):
                    taken[path] = np.asarray(host)  # already a private copy
                    continue
                a = np.asarray(host)
                buf = pool.get(path)
                if buf is None or buf.shape != a.shape or buf.dtype != a.dtype:
                    buf = np.empty(a.shape, a.dtype)
                    pool[path] = buf
                np.copyto(buf, a)
                taken[path] = buf
            out[name] = taken
        return out


@dataclass
class _Captured:
    """Everything frozen at the capture point."""
    step: int
    slot: _StagingSlot
    host_state: Dict[str, Dict[str, np.ndarray]]
    structure: Dict[str, Any]
    kinds: Dict[str, str]
    log_json: Any
    job_meta: Dict[str, Any]
    capture_seconds: float


class SnapshotHandle:
    """Caller's view of one snapshot moving through the pipeline."""

    def __init__(self, step: int) -> None:
        self.step = step
        self._future: Future = Future()
        self.timings: Dict[str, float] = {}

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        """Block until committed; returns the manifest."""
        return self._future.result(timeout)

    # Future-compatible alias so legacy callers treating save()'s return
    # value as a concurrent.futures.Future keep working
    def add_done_callback(self, fn) -> None:
        self._future.add_done_callback(fn)


class AsyncSnapshotter:
    """The capture/encode/commit pipeline (see module docstring)."""

    def __init__(
        self,
        backend: CheckpointBackend,
        *,
        codec_by_kind: Optional[Dict[str, str]] = None,
        delta_base_interval: int = 1,
        backpressure: str = "block",
        writers: int = 4,
        compress: bool = True,
        keep_last: Optional[int] = None,
        prune_oplog: bool = True,
        depth: Optional[int] = None,
    ) -> None:
        assert backpressure in ("block", "skip"), backpressure
        assert delta_base_interval >= 1
        self.backend = backend
        self.codec_by_kind = codec_by_kind or {}
        self.delta_base_interval = delta_base_interval
        self.backpressure = backpressure
        self.compress = compress
        self.keep_last = keep_last
        self.prune_oplog = prune_oplog
        if depth is None:  # +1 slot to keep capture overlapped while the
            depth = 2 if delta_base_interval == 1 else 3  # base is pinned
        self._slots = [_StagingSlot() for _ in range(depth)]
        self._cond = threading.Condition()
        self._encode_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="snap-encode")  # ordered
        self._writer_pool = ThreadPoolExecutor(
            max_workers=writers, thread_name_prefix="snap-write")
        self._inflight: List[SnapshotHandle] = []
        self._last_error: Optional[BaseException] = None
        # previous snapshot kept as the XOR base: (step, host_state, slot)
        self._prev: Optional[Tuple[int, Dict[str, Dict[str, np.ndarray]],
                                   _StagingSlot]] = None
        self._chain_len = 0
        self.stats: Dict[str, Any] = {
            "saves": 0, "skipped": 0, "failed": 0, "chain_links": 0,
            "bytes_written": 0, "bytes_logical": 0,
            "save_seconds": 0.0, "capture_seconds": 0.0,
            "encode_commit_seconds": 0.0,
        }

    # --- capture (caller thread) ------------------------------------------

    def _acquire_slot(self, must_take: bool = False
                      ) -> Optional[_StagingSlot]:
        with self._cond:
            while True:
                for s in self._slots:
                    if not s.busy:
                        s.busy = True
                        return s
                if self.backpressure == "skip" and not must_take:
                    return None
                self._cond.wait()

    def _release_slot(self, slot: _StagingSlot) -> None:
        with self._cond:
            slot.busy = False
            self._cond.notify_all()

    def snapshot(self, step: int, upper: UpperHalf, oplog: OpLog,
                 job_meta: Optional[Dict[str, Any]] = None,
                 must_take: bool = False) -> Optional[SnapshotHandle]:
        """Capture now; encode + commit in the background. Returns None
        iff the pipeline is saturated and backpressure policy is "skip".
        ``must_take`` overrides a "skip" policy (a caller that asked to
        block has said it will wait — dropping would lose e.g. the final
        checkpoint of a run)."""
        slot = self._acquire_slot(must_take=must_take)
        if slot is None:
            self.stats["skipped"] += 1
            return None
        t0 = time.monotonic()
        try:
            host_state = slot.capture(upper)
            cap = _Captured(
                step=step,
                slot=slot,
                host_state=host_state,
                structure=upper.structure(),
                kinds={name: e.kind for name, e in upper.items()},
                log_json=(oplog.prune() if self.prune_oplog
                          else oplog).to_json(),
                job_meta=job_meta or {},
                capture_seconds=time.monotonic() - t0,
            )
        except BaseException:
            self._release_slot(slot)
            raise
        handle = SnapshotHandle(step)
        handle.timings["capture"] = cap.capture_seconds
        self.stats["capture_seconds"] += cap.capture_seconds
        with self._cond:
            self._inflight.append(handle)
        self._encode_pool.submit(self._encode_and_commit, cap, handle)
        return handle

    # --- encode + commit (pipeline threads) -------------------------------

    def _encode_and_commit(self, cap: _Captured,
                           handle: SnapshotHandle) -> None:
        t0 = time.monotonic()
        try:
            manifest = self._do_encode_commit(cap)
        except BaseException as e:
            with self._cond:
                self._last_error = e   # drain() re-raises even if the
                self.stats["failed"] += 1  # handle is retired by then
            self._retire(cap.slot, handle, keep_as_prev=False)
            handle._future.set_exception(e)
            return
        dt = time.monotonic() - t0
        handle.timings["encode_commit"] = dt
        self.stats["saves"] += 1
        self.stats["encode_commit_seconds"] += dt
        self.stats["save_seconds"] += cap.capture_seconds + dt
        self._retire(cap.slot, handle,
                     keep_as_prev=self.delta_base_interval > 1,
                     step=cap.step, host_state=cap.host_state)
        handle._future.set_result(manifest)

    def _do_encode_commit(self, cap: _Captured) -> Dict[str, Any]:
        chain = (self.delta_base_interval > 1 and self._prev is not None
                 and self._chain_len < self.delta_base_interval - 1)
        base_step = self._prev[0] if chain else None
        base_state = self._prev[1] if chain else {}

        writer = _BlobWriter(self.backend, self._writer_pool)
        entries_manifest: Dict[str, Any] = {}
        written = logical = 0
        for name, leaves in cap.host_state.items():
            codec = self.codec_by_kind.get(cap.kinds[name])
            leaf_metas: Dict[str, Any] = {}
            for path, arr in leaves.items():
                prev_arr = None
                if chain and not deltamod.codec_applicable(arr, codec):
                    prev_arr = base_state.get(name, {}).get(path)
                m = deltamod.encode_leaf(
                    arr, writer.put, writer.has,
                    codec=codec, prev=prev_arr, compress=self.compress)
                written += m.pop("bytes_written", 0)
                logical += arr.nbytes
                leaf_metas[path] = m
            entries_manifest[name] = {"kind": cap.kinds[name],
                                      "leaves": leaf_metas}
        writer.drain()  # every blob durable before the manifest commits
        manifest = {
            "format": MANIFEST_FORMAT,
            "step": cap.step,
            "base_step": base_step,
            "entries": entries_manifest,
            "oplog": cap.log_json,
            "structure": cap.structure,
            "job": cap.job_meta,
        }
        self.backend.commit_manifest(cap.step, manifest)
        self._chain_len = self._chain_len + 1 if chain else 0
        if chain:
            self.stats["chain_links"] += 1
        self.stats["bytes_written"] += written
        self.stats["bytes_logical"] += logical
        if self.keep_last is not None:
            try:
                self.gc(self.keep_last)
            except Exception:  # noqa: BLE001 — snapshot IS committed;
                # a transient retention failure must not report it lost
                self.stats["gc_failures"] = \
                    self.stats.get("gc_failures", 0) + 1
        return manifest

    def _retire(self, slot: _StagingSlot, handle: SnapshotHandle,
                keep_as_prev: bool, step: int = -1,
                host_state=None) -> None:
        """Slot bookkeeping after a snapshot leaves the pipeline: the
        committed slot becomes the next XOR base (when chaining); the
        base it replaced is freed. The handle's result is set by the
        caller right after — anyone blocked on it wakes with the slots
        already released."""
        with self._cond:
            old_prev = self._prev
            if keep_as_prev:
                self._prev = (step, host_state, slot)
            else:
                self._prev = None
                slot.busy = False
            if old_prev is not None and old_prev[2] is not slot:
                old_prev[2].busy = False
            self._inflight = [h for h in self._inflight if h is not handle]
            self._cond.notify_all()

    # --- drain / shutdown --------------------------------------------------

    def drain(self) -> None:
        """Block until every in-flight snapshot committed (or failed),
        then re-raise the most recent failure since the last drain —
        including one that completed before drain was called, so
        fire-and-forget callers (snapshot(); ...; wait()) cannot
        silently lose checkpoints."""
        with self._cond:
            pending = list(self._inflight)
        for h in pending:
            try:
                h.result()
            except BaseException:  # noqa: BLE001 — raised via _last_error
                pass
        with self._cond:
            err, self._last_error = self._last_error, None
        if err is not None:
            raise err

    def consume_error(self, err: BaseException) -> None:
        """A caller that already received `err` from a handle (blocking
        save) takes ownership of it, so a later unrelated drain() does
        not re-raise a failure that was handled and possibly retried."""
        with self._cond:
            if self._last_error is err:
                self._last_error = None

    def close(self) -> None:
        try:
            self.drain()
        finally:
            self._encode_pool.shutdown(wait=True)
            self._writer_pool.shutdown(wait=True)

    # --- gc ----------------------------------------------------------------

    def gc(self, keep_last: int) -> None:
        """Drop all but the last `keep_last` checkpoints — plus the
        transitive base closure of the kept ones, so every survivor's
        delta chain stays restorable — then GC unreferenced blobs."""
        steps = self.backend.list_steps()
        have = set(steps)
        # keep_last <= 0 means "no retention limit", never "drop all"
        keep = set(steps[-keep_last:]) if keep_last > 0 else set(steps)
        frontier = list(keep)
        manifests: Dict[int, Dict[str, Any]] = {}
        while frontier:
            s = frontier.pop()
            m = manifests.get(s) or self.backend.get_manifest(s)
            manifests[s] = m
            b = m.get("base_step")
            if b is not None and b in have and b not in keep:
                keep.add(b)
                frontier.append(b)
        for s in steps:
            if s not in keep:
                self.backend.delete_step(s)
        referenced: set = set()
        for s in keep:
            referenced |= deltamod.referenced_hashes(manifests[s])
        self.backend.gc_blobs(referenced)


class _BlobWriter:
    """Fans blob writes out to the writer pool with a bounded in-flight
    window; drain() rejoins before the manifest commit.

    ``has`` answers "is this blob durable or already queued by me" —
    the backend alone can't, because a queued write hasn't landed yet,
    and asking it directly would re-write (and re-count) every repeated
    chunk within one snapshot (e.g. zero-initialized weights)."""

    def __init__(self, backend: CheckpointBackend,
                 pool: ThreadPoolExecutor,
                 max_pending: int = MAX_PENDING_WRITES) -> None:
        self._backend = backend
        self._pool = pool
        self._sem = threading.Semaphore(max_pending)
        self._futures: List[Future] = []
        self._queued: set = set()  # touched only by the encode thread

    def has(self, name: str) -> bool:
        return name in self._queued or self._backend.has_blob(name)

    def put(self, name: str, data: bytes) -> None:
        self._queued.add(name)
        self._sem.acquire()
        self._futures.append(self._pool.submit(self._write, name, data))

    def _write(self, name: str, data: bytes) -> None:
        try:
            self._backend.put_blob(name, data)
        finally:
            self._sem.release()

    def drain(self) -> None:
        for f in self._futures:
            f.result()
        self._futures.clear()


# ---------------------------------------------------------------------------
# restore side: delta chain -> full state
# ---------------------------------------------------------------------------

def manifest_chain_steps(backend: CheckpointBackend, step: int) -> List[int]:
    """base-first list of steps whose manifests `step` depends on."""
    chain = []
    s: Optional[int] = step
    while s is not None:
        m = backend.get_manifest(s)
        chain.append(s)
        s = m.get("base_step")
    chain.reverse()
    return chain


def _decode_chain_leaf(manifests: List[Dict[str, Any]], backend,
                       name: str, path: str) -> np.ndarray:
    """Decode one leaf of the final manifest: walk base links back only
    as far as its run of xor modes reaches (a full or codec leaf needs
    no predecessor), then decode forward, XOR-applying each link."""
    i = len(manifests) - 1
    while i > 0 and (manifests[i]["entries"][name]["leaves"][path]
                     .get("mode") == "xor"):
        i -= 1  # xor decodes against the predecessor's value
    val: Optional[np.ndarray] = None
    for m in manifests[i:]:
        val = deltamod.decode_leaf(
            m["entries"][name]["leaves"][path], backend.get_blob, prev=val)
    return val


# below this leaf count a worker pool costs more than it hides; tiny
# checkpoints (scalars + a couple of tensors) decode inline
_PARALLEL_DECODE_MIN_LEAVES = 4


def materialize_manifest_chain(
    backend: CheckpointBackend, step: int, workers: Optional[int] = None,
    skip_entries=(),
) -> Tuple[Dict[str, Any], Dict[str, Dict[str, np.ndarray]]]:
    """Delta chain -> full state. Each leaf decodes independently (its
    own xor-run walk), so leaves fan out across a worker pool — restore
    latency is bounded by the largest leaf's chain, not the sum of all
    of them. Leaves that exist only in intermediate manifests — or are
    non-xor there — are never decoded, so restore cost per leaf stays
    O(xor-run length), not O(chain length).

    ``workers``: decode pool size; default scales with the host, 1
    forces the serial path (both orders produce identical arrays).
    ``skip_entries``: entry names to leave undecoded (absent from the
    result) — a caller that rebuilds an entry from scratch, like the
    serving engine re-slotting its KV cache, shouldn't pay its chain."""
    manifests = [backend.get_manifest(s)
                 for s in manifest_chain_steps(backend, step)]
    final = manifests[-1]
    skip = set(skip_entries)
    tasks = [(name, path) for name, e in final["entries"].items()
             if name not in skip for path in e["leaves"]]
    if workers is None:
        import os
        workers = min(8, os.cpu_count() or 1)
    if workers > 1 and len(tasks) >= _PARALLEL_DECODE_MIN_LEAVES:
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="chain-decode") as pool:
            vals = list(pool.map(
                lambda t: _decode_chain_leaf(manifests, backend, *t), tasks))
    else:
        vals = [_decode_chain_leaf(manifests, backend, name, path)
                for name, path in tasks]
    entries: Dict[str, Dict[str, np.ndarray]] = {}
    for (name, path), val in zip(tasks, vals):
        entries.setdefault(name, {})[path] = val
    # entries present in the manifest but empty of leaves (e.g. an empty
    # request queue) must still appear in the restored state
    for name in final["entries"]:
        if name not in skip:
            entries.setdefault(name, {})
    return final, entries
