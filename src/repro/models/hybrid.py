"""Griffin/RecurrentGemma blocks: RG-LRU gated linear recurrence + local
attention, repeating block pattern (rglru, rglru, attn).

Training uses jax.lax.associative_scan over the sequence (O(S log S)
depth, exact); decode is the O(1) recurrence. The recurrence gates are
per-channel (diagonal) — a documented simplification of RecurrentGemma's
block-diagonal gate projections that preserves the memory/compute
character (see DESIGN.md §9).

Sharding: the recurrent width shards over ``model`` (all per-channel ops
are elementwise, so a width-sharded RG-LRU needs zero collectives — this
is why long_500k decode on this arch is ICI-quiet).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models.layers import (
    norm_template, apply_norm, attention_template, attention_forward,
    mlp_template, mlp_forward,
)

_C = 8.0  # RG-LRU gate sharpness constant (Griffin eq. 4)


def rglru_block_template(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    w = cfg.rglru_width or d
    cw = 4
    return {
        "norm": norm_template(cfg),
        "w_x": P((d, w), ("embed", "ff"), fan_in=d),
        "w_gate_branch": P((d, w), ("embed", "ff"), fan_in=d),
        "conv_w": P((cw, w), (None, "ff"), init="scaled", fan_in=cw),
        "conv_b": P((w,), ("ff",), init="zeros"),
        "rg_lambda": P((w,), ("ff",), init="rglru_a", dtype="float32"),
        "gate_a_w": P((w,), ("ff",), init="normal", fan_in=1, scale=0.1,
                      dtype="float32"),
        "gate_a_b": P((w,), ("ff",), init="zeros", dtype="float32"),
        "gate_x_w": P((w,), ("ff",), init="normal", fan_in=1, scale=0.1,
                      dtype="float32"),
        "gate_x_b": P((w,), ("ff",), init="zeros", dtype="float32"),
        "w_out": P((w, d), ("ff", "embed"), fan_in=w),
        "mlp_norm": norm_template(cfg),
        "mlp": mlp_template(cfg),
    }


def attn_block_template(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "norm": norm_template(cfg),
        "attn": attention_template(cfg),
        "mlp_norm": norm_template(cfg),
        "mlp": mlp_template(cfg),
    }


def _causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv, x: [B,S,W], w: [cw,W]."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, i:i + S] * w[i] for i in range(cw))
    new_state = xp[:, xp.shape[1] - (cw - 1):]
    return y + b, new_state


def rg_lru(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
           h0: Optional[jax.Array] = None):
    """RG-LRU recurrence. x, r, i: [B,S,W] (f32); lam: [W].

    a_t = exp(-c * softplus(lam) * r_t);  h_t = a_t h_{t-1}
          + sqrt(1 - a_t^2) * (i_t * x_t)
    Returns (h [B,S,W], h_last [B,W])."""
    log_a = -_C * jax.nn.softplus(lam) * r            # [B,S,W], <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    gate = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = gate * (i * x)
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0.astype(b.dtype)[:, None], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = Bc if h0 is None else Bc[:, 1:]
    return h, h[:, -1]


def rg_lru_step(x, r, i, lam, h_prev):
    """One decode step. x, r, i: [B,1,W]; h_prev: [B,W]."""
    log_a = -_C * jax.nn.softplus(lam) * r[:, 0]
    a = jnp.exp(log_a)
    gate = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    h = a * h_prev + gate * (i[:, 0] * x[:, 0])
    return h[:, None], h


def rglru_block_forward(cfg, p, x, cache=None):
    """Recurrent mixer + MLP (both residual). x: [B,S,D]."""
    B, S, D = x.shape
    h = apply_norm(cfg, p["norm"], x)
    xb = h @ p["w_x"]                                   # [B,S,W]
    gb = jax.nn.gelu(h @ p["w_gate_branch"])

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)

    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["gate_a_w"] + p["gate_a_b"])
    ig = jax.nn.sigmoid(xf * p["gate_x_w"] + p["gate_x_b"])

    if cache is not None and S == 1:
        y, h_last = rg_lru_step(xf, r, ig, p["rg_lambda"], cache["state"])
    else:
        h0 = cache["state"] if cache is not None else None
        y, h_last = rg_lru(xf, r, ig, p["rg_lambda"], h0)

    y = (y.astype(x.dtype) * gb) @ p["w_out"]
    x = x + y
    m = mlp_forward(cfg, p["mlp"], apply_norm(cfg, p["mlp_norm"], x))
    x = x + m

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": h_last}
    return x, new_cache


def attn_block_forward(cfg, p, x, positions, cache=None):
    h = apply_norm(cfg, p["norm"], x)
    a, new_kv = attention_forward(
        cfg, p["attn"], h, positions,
        window=cfg.attn_window, cache=cache)
    x = x + a
    m = mlp_forward(cfg, p["mlp"], apply_norm(cfg, p["mlp_norm"], x))
    x = x + m
    return x, new_cache_or_none(new_kv)


def new_cache_or_none(kv):
    return kv


def rglru_cache_spec(cfg: ModelConfig, batch: int):
    w = cfg.rglru_width or cfg.d_model
    bf16 = jnp.dtype(cfg.dtype)
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, w), bf16),
        "state": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }
