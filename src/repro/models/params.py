"""Parameter templates: one declaration drives concrete init, abstract
(ShapeDtypeStruct) init for the dry-run, and logical sharding specs.

A model declares a nested dict of ``P`` leaves. From that single template
we derive:
  * ``init_concrete``  — real arrays (smoke tests / examples),
  * ``init_abstract``  — ShapeDtypeStructs (dry-run: no allocation),
  * ``logical_specs``  — pytree of logical-axis tuples consumed by
                         parallel.sharding to build NamedShardings.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    """One parameter leaf: shape + logical axis names + init style."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 1.0          # stddev multiplier for normal/scaled
    fan_in: int = 0             # for scaled init: std = scale/sqrt(fan_in)
    dtype: Optional[str] = None  # override model dtype (e.g. f32 norms)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def stacked(n_layers: int, tree: Dict[str, Any]) -> Dict[str, Any]:
    """Add a leading 'layers' axis to every leaf (for lax.scan)."""

    def f(leaf: P) -> P:
        return P(
            shape=(n_layers,) + leaf.shape,
            axes=("layers",) + leaf.axes,
            init=leaf.init,
            scale=leaf.scale,
            fan_in=leaf.fan_in,
            dtype=leaf.dtype,
        )

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, P))


def _leaf_dtype(leaf: P, default: str):
    return jnp.dtype(leaf.dtype or default)


def init_abstract(template: Dict[str, Any], default_dtype: str) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree — zero allocation, dry-run safe."""

    def f(leaf: P):
        return jax.ShapeDtypeStruct(leaf.shape, _leaf_dtype(leaf, default_dtype))

    return jax.tree.map(f, template, is_leaf=lambda x: isinstance(x, P))


def logical_specs(template: Dict[str, Any]) -> Dict[str, Any]:
    def f(leaf: P):
        return tuple(leaf.axes)

    return jax.tree.map(f, template, is_leaf=lambda x: isinstance(x, P))


def init_concrete(template: Dict[str, Any], default_dtype: str, rng: jax.Array) -> Dict[str, Any]:
    """Materialize real parameters. Deterministic in ``rng``: each leaf's
    key is folded from the hash of its path, so adding/removing params
    does not perturb sibling initializations (important for bitwise
    restore tests across code revisions)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: isinstance(x, P))[0]
    treedef = jax.tree.structure(template, is_leaf=lambda x: isinstance(x, P))

    out = []
    for path, leaf in leaves_with_paths:
        pathstr = jax.tree_util.keystr(path)
        key = jax.random.fold_in(rng, _stable_hash(pathstr))
        dt = _leaf_dtype(leaf, default_dtype)
        if leaf.init == "zeros":
            arr = jnp.zeros(leaf.shape, dt)
        elif leaf.init == "ones":
            arr = jnp.ones(leaf.shape, dt)
        elif leaf.init in ("normal", "scaled"):
            fan_in = leaf.fan_in or (leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1])
            std = leaf.scale / np.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(key, leaf.shape, jnp.float32) * std).astype(dt)
        elif leaf.init == "rglru_a":
            # RG-LRU forget-gate param: softplus-inverse of decay in [0.9, 0.999]
            u = jax.random.uniform(key, leaf.shape, jnp.float32, 0.9, 0.999)
            arr = jnp.log(jnp.expm1(-jnp.log(u))).astype(dt)  # softplus^-1(-log a)
        elif leaf.init == "ssm_a":
            # mamba2 A_log: log of uniform [1, 16]
            u = jax.random.uniform(key, leaf.shape, jnp.float32, 1.0, 16.0)
            arr = jnp.log(u).astype(dt)
        elif leaf.init == "ssm_dt":
            # dt bias: softplus^-1 of uniform log-spaced [1e-3, 1e-1]
            lo, hi = np.log(1e-3), np.log(1e-1)
            u = jnp.exp(jax.random.uniform(key, leaf.shape, jnp.float32, lo, hi))
            arr = (u + jnp.log(-jnp.expm1(-u))).astype(dt)
        else:
            raise ValueError(f"unknown init {leaf.init!r}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def _stable_hash(s: str) -> int:
    """Deterministic across processes (unlike builtin hash)."""
    h = 2166136261
    for ch in s.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h


def count_params(tree) -> int:
    sizes = [int(np.prod(l.shape)) for l in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))]
    return int(sum(sizes))
