"""Shared neural-net layers: norms, RoPE, GQA attention (training /
prefill / decode, full or windowed), and MLP variants.

Attention for long sequences is implemented as a *chunked, numerically
stable streaming softmax* (the flash-attention recurrence) in pure JAX
lax.scan — this bounds peak activation memory structurally (no [S, S]
score materialization), keeps HLO size O(1) in sequence length, and is
the same blocking the Pallas kernel (kernels/flash_attention) uses on
real TPUs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import contextvars

from repro.configs.base import ModelConfig
from repro.models.params import P

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free

# Interior tensor-parallel constraint, installed by train/serving step
# builders (see train.step.make_call_options). Applied to the TP-sharded
# interior activations (MLP hidden, attention heads) so the SPMD
# partitioner reshards *activations* (Megatron ag/rs) instead of
# all-gathering weights to full — observed 8x collective inflation on
# qwen1.5-110b without this (EXPERIMENTS.md §Perf iter3).
_TP_CONSTRAINT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_tp_constraint", default=None)


def set_tp_constraint(fn):
    """fn(x, sharded_dim) -> x; returns a contextvar token."""
    return _TP_CONSTRAINT.set(fn)


def _tp(x: jax.Array, dim: int) -> jax.Array:
    fn = _TP_CONSTRAINT.get()
    return fn(x, dim) if fn is not None else x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_template(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, P]:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": P((d,), (None,), init="ones", dtype="float32"),
                "bias": P((d,), (None,), init="zeros", dtype="float32")}
    return {"scale": P((d,), (None,), init="zeros", dtype="float32")}


def apply_norm(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention parameter template
# ---------------------------------------------------------------------------

def attention_template(cfg: ModelConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    t: Dict[str, Any] = {
        "wq": P((d, nh, h), ("embed", "heads", None), fan_in=d),
        "wk": P((d, nkv, h), ("embed", "kv_heads", None), fan_in=d),
        "wv": P((d, nkv, h), ("embed", "kv_heads", None), fan_in=d),
        "wo": P((nh, h, d), ("heads", None, "embed"), fan_in=nh * h),
    }
    if cfg.qkv_bias:
        t["bq"] = P((nh, h), ("heads", None), init="zeros")
        t["bk"] = P((nkv, h), ("kv_heads", None), init="zeros")
        t["bv"] = P((nkv, h), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = P((h,), (None,), init="zeros", dtype="float32")
        t["k_norm"] = P((h,), (None,), init="zeros", dtype="float32")
    return t


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — training / prefill
# ---------------------------------------------------------------------------

def _pad_axis(x: jax.Array, axis: int, to_mult: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % to_mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def chunked_attention(
    q: jax.Array,              # [B, Sq, Hkv, G, hd]
    k: jax.Array,              # [B, Skv, Hkv, hd]
    v: jax.Array,              # [B, Skv, Hkv, hd]
    q_pos: jax.Array,          # [B, Sq] int32
    kv_pos: jax.Array,         # [B, Skv] int32 (-1 = invalid slot)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    chunk: int = 1024,
) -> jax.Array:
    """Streaming-softmax attention over kv chunks. Returns [B,Sq,Hkv,G,hd].

    Positions drive masking (supports ring-buffer caches whose slots are
    out of order). f32 accumulation throughout.
    """
    B, Sq, Hkv, G, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    chunk = min(chunk, k.shape[1])

    kp = _pad_axis(k, 1, chunk)
    vp = _pad_axis(v, 1, chunk)
    pp = _pad_axis(kv_pos, 1, chunk, value=-1)
    nkc = kp.shape[1] // chunk

    # [nkc, B, chunk, ...]
    ks = kp.reshape(B, nkc, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nkc, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    ps = pp.reshape(B, nkc, chunk).transpose(1, 0, 2)

    qf = (q.astype(jnp.float32) * scale)

    def body(carry, kv_chunk):
        m, l, acc = carry
        kc, vc, pc = kv_chunk
        # scores: [B, Sq, Hkv, G, chunk]
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kc.astype(jnp.float32))
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        valid = pc[:, None, :] >= 0  # [B, 1, chunk]
        if causal:
            valid = valid & (pc[:, None, :] <= q_pos[:, :, None])
        if window > 0:
            valid = valid & (q_pos[:, :, None] - pc[:, None, :] < window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32)
    # remat the kv-chunk body: backward recomputes the [.., Sq, chunk]
    # score/prob tiles instead of saving one per chunk (which would cost
    # nkc x B x Sq x H x chunk x 4B of live temps per layer)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def dense_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_pos: jax.Array, kv_pos: jax.Array,
    *, causal: bool = True, window: int = 0, softcap: float = 0.0,
) -> jax.Array:
    """One-shot softmax attention (decode and short-seq paths).

    Shapes as chunked_attention. XLA shards the kv/seq axis freely; with a
    seq-sharded cache the partial-softmax combine lowers to small
    all-reduces (flash-decoding pattern).
    """
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    valid = kv_pos[:, None, :] >= 0
    if causal:
        valid = valid & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        valid = valid & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(axis=-1), 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def attention_forward(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                   # [B, S, D]
    positions: jax.Array,           # [B, S]
    *,
    window: int = 0,
    causal: bool = True,
    cache: Optional[Dict[str, jax.Array]] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    use_rope: bool = True,
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (output [B,S,D], updated cache).

    cache layouts (created by serving.kv_cache):
      full:   {"k": [B,Smax,Hkv,hd], "v": ..., "pos": [B,Smax] int32}
      window: same with Smax == window, ring-buffer indexed by position.
    cross_kv: precomputed encoder (k, v) for cross-attention; cache unused.
    """
    B, S, D = x.shape
    h = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    g = nh // nkv

    q = _tp(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), 2)
    if "bq" in p:
        q = q + p["bq"]
    if cross_kv is None:
        k = _tp(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), 2)
        v = _tp(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), 2)
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope_apply(q, positions, cfg.rope_theta)
        if cross_kv is None:
            k = rope_apply(k, positions, cfg.rope_theta)

    new_cache = cache
    if cross_kv is not None:
        kv_heads = k.shape[2]
        kv_pos = jnp.broadcast_to(
            jnp.arange(k.shape[1], dtype=jnp.int32)[None], (B, k.shape[1]))
        qg = q.reshape(B, S, kv_heads, nh // kv_heads, h)
        out = dense_attention(qg, k, v, positions, kv_pos, causal=False)
    elif cache is not None:
        smax = cache["k"].shape[1]
        if window > 0 and S > smax:
            # prefill into a ring buffer: only the trailing `window`
            # positions can ever be attended to — write just those.
            k_w, v_w, pos_w = k[:, -smax:], v[:, -smax:], positions[:, -smax:]
            slot = pos_w % smax
            ck = _scatter_rows(cache["k"], slot, k_w)
            cv = _scatter_rows(cache["v"], slot, v_w)
            cpos = _scatter_rows(cache["pos"], slot, pos_w)
        else:
            slot = positions % smax if window > 0 else positions
            ck = _scatter_rows(cache["k"], slot, k)
            cv = _scatter_rows(cache["v"], slot, v)
            cpos = _scatter_rows(cache["pos"], slot, positions)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        qg = q.reshape(B, S, nkv, g, h)
        if S == 1:
            # decode: attend over the cache (seq axis freely shardable)
            att_k, att_v, att_pos = ck, cv, cpos
        else:
            # prefill: attend over the inputs (a ring cache only holds the
            # trailing window; every query still sees its own context here)
            att_k, att_v, att_pos = k, v, positions
        if S == 1 or att_k.shape[1] <= 2048:
            out = dense_attention(qg, att_k, att_v, positions, att_pos,
                                  causal=causal, window=window,
                                  softcap=cfg.attn_logit_softcap)
        else:
            out = chunked_attention(qg, att_k, att_v, positions, att_pos,
                                    causal=causal, window=window,
                                    softcap=cfg.attn_logit_softcap,
                                    chunk=attn_chunk)
    else:
        qg = q.reshape(B, S, nkv, g, h)
        kv_pos = positions
        if S <= 2048:
            out = dense_attention(qg, k, v, positions, kv_pos,
                                  causal=causal, window=window,
                                  softcap=cfg.attn_logit_softcap)
        else:
            out = chunked_attention(qg, k, v, positions, kv_pos,
                                    causal=causal, window=window,
                                    softcap=cfg.attn_logit_softcap,
                                    chunk=attn_chunk)

    out = _tp(out.reshape(B, S, nh, h), 2)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def _scatter_rows(buf: jax.Array, slots: jax.Array, rows: jax.Array) -> jax.Array:
    """buf: [B, Smax, ...]; slots: [B, S]; rows: [B, S, ...] -> updated buf.

    S is typically 1 (decode) or Smax (prefill into an empty cache)."""
    B, S = slots.shape
    if S == buf.shape[1] and rows.shape[:2] == buf.shape[:2]:
        # full overwrite in slot order (prefill fills every slot exactly once
        # when S == Smax and slots is a permutation — true for pos 0..S-1)
        return rows.astype(buf.dtype)
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
    return buf.at[b_idx, slots].set(rows.astype(buf.dtype))


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_template(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.act in ("silu", "gelu_glu"):
        return {
            "w_gate": P((d, f), ("embed", "ff"), fan_in=d),
            "w_up": P((d, f), ("embed", "ff"), fan_in=d),
            "w_down": P((f, d), ("ff", "embed"), fan_in=f),
        }
    t = {
        "w_in": P((d, f), ("embed", "ff"), fan_in=d),
        "w_out": P((f, d), ("ff", "embed"), fan_in=f),
    }
    if cfg.norm == "layernorm":  # bias-ful families (starcoder2, whisper)
        t["b_in"] = P((f,), ("ff",), init="zeros")
        t["b_out"] = P((d,), (None,), init="zeros")
    return t


def mlp_forward(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        h = jax.nn.silu(_tp(x @ p["w_gate"], -1)) * _tp(x @ p["w_up"], -1)
        return h @ p["w_down"]
    if cfg.act == "gelu_glu":
        h = jax.nn.gelu(_tp(x @ p["w_gate"], -1)) * _tp(x @ p["w_up"], -1)
        return h @ p["w_down"]
    h = _tp(x @ p["w_in"], -1)
    if "b_in" in p:
        h = h + p["b_in"]
    h = jax.nn.gelu(h)
    y = h @ p["w_out"]
    if "b_out" in p:
        y = y + p["b_out"]
    return y
