"""Mixture-of-Experts FFN with expert parallelism.

Dispatch strategy (TPU-native, see DESIGN.md §5): activations are
replicated across the ``model`` axis (standard TP residual stream), expert
weights are sharded over ``model`` (E/M experts per shard). Each model
shard locally dispatches the tokens routed to *its* experts — a
scatter-add into an [E_local * C, D] capacity buffer, never a [T, E, C]
one-hot — computes its experts, gathers back, and the combine is a psum
over ``model`` (the same all-reduce pattern a dense TP FFN would pay).

This avoids GShard's giant dispatch einsum and needs no all-to-all in the
baseline. An all-to-all + sequence-sharded variant (cuts combine bytes by
the TP degree) is the §Perf hillclimb for collective-bound MoE cells —
see moe_forward(seq_sharded=True).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models import layers as L
from repro.parallel import context as pctx


def moe_template(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.resolved_moe_d_ff, cfg.n_experts
    t: Dict[str, Any] = {
        "router": P((d, e), ("embed", None), fan_in=d, dtype="float32"),
        "w_gate": P((e, d, f), ("experts", "embed", "ff"), fan_in=d),
        "w_up": P((e, d, f), ("experts", "embed", "ff"), fan_in=d),
        "w_down": P((e, f, d), ("experts", "ff", "embed"), fan_in=f),
    }
    if cfg.n_shared_experts:
        t["shared"] = L.mlp_template(cfg, cfg.n_shared_experts * f)
    return t


def _capacity(n_tokens: int, k: int, n_experts: int, cf: float) -> int:
    c = int(np.ceil(cf * n_tokens * k / n_experts))
    return max(8, int(np.ceil(c / 8)) * 8)


def _local_expert_ffn(buf: jax.Array, w_gate, w_up, w_down, capacity: int):
    """buf: [E_local * C + 1, D] -> same shape through the local experts."""
    el = w_gate.shape[0]
    xb = buf[:-1].reshape(el, capacity, -1)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xb, w_up)
    yb = jnp.einsum("ecf,efd->ecd", h, w_down)
    y = yb.reshape(el * capacity, -1)
    return jnp.concatenate([y, jnp.zeros_like(buf[-1:])], axis=0)


def _dispatch_compute_combine(
    x: jax.Array,             # [T, D] local tokens
    gates: jax.Array,         # [T, K] f32
    idx: jax.Array,           # [T, K] int32 global expert ids
    w_gate, w_up, w_down,     # local expert weights [El, ...]
    shard_index,              # scalar: which expert shard am I
    n_shards: int,
    capacity: int,
) -> jax.Array:
    """Pure per-shard MoE math. Works for n_shards == 1 (tests) too."""
    t, k = idx.shape
    el = w_gate.shape[0]
    d = x.shape[-1]

    local = idx - shard_index * el                     # [T, K]
    mine = (local >= 0) & (local < el)
    local_c = jnp.where(mine, local, 0)

    # rank of each (token, choice) within its expert, counted jointly over
    # all K choices so capacity is shared. [T*K, El] cumsum — El is per-shard
    # (small), so this stays tiny where a [T, E_global, C] one-hot would not.
    onehot = (local_c.reshape(t * k, 1) == jnp.arange(el)[None, :]) & \
        mine.reshape(t * k, 1)
    ranks = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    my_rank = jnp.sum(jnp.where(onehot, ranks, 0), axis=-1).reshape(t, k)

    ok = mine & (my_rank < capacity)
    overflow = el * capacity                           # drop slot
    slots = jnp.where(ok, local_c * capacity + my_rank, overflow)  # [T, K]

    buf = jnp.zeros((el * capacity + 1, d), x.dtype)
    for j in range(k):                                  # K is small (1 or 8)
        buf = buf.at[slots[:, j]].add(x)                # no token gather

    buf = _local_expert_ffn(buf, w_gate, w_up, w_down, capacity)

    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + buf[slots[:, j]] * gates[:, j].astype(x.dtype)[:, None]
    return out


def route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """Router: top-k gates + aux load-balance loss. x: [B,S,D]."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = cfg.n_experts
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=2), axis=(0, 1))
    prob_frac = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(dispatch_frac * prob_frac)
    return gates, idx.astype(jnp.int32), aux


def moe_forward(
    cfg: ModelConfig,
    p: Dict[str, Any],
    x: jax.Array,                 # [B, S, D]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    gates, idx, aux = route(cfg, p["router"], x)

    ctx = pctx.current()
    m_size = ctx.model_size() if ctx is not None else 1
    d_size = ctx.data_size() if ctx is not None else 1
    local_tokens = (B // d_size) * S
    cap = _capacity(local_tokens, cfg.experts_per_token, cfg.n_experts,
                    cfg.capacity_factor)

    if ctx is None or (m_size == 1 and d_size == 1):
        y = _dispatch_compute_combine(
            x.reshape(B * S, D), gates.reshape(B * S, -1),
            idx.reshape(B * S, -1), p["w_gate"], p["w_up"], p["w_down"],
            shard_index=0, n_shards=1, capacity=cap)
        y = y.reshape(B, S, D)
    else:
        Pspec = jax.sharding.PartitionSpec
        batch_axes = ctx.batch_spec_axes
        tok_spec = Pspec(batch_axes, None, None)
        gate_spec = Pspec(batch_axes, None, None)
        w_spec = Pspec(ctx.model_axis, None, None)

        def shard_fn(xb, gb, ib, wg, wu, wd):
            m = jax.lax.axis_index(ctx.model_axis) if ctx.model_axis else 0
            bl, sl, _ = xb.shape
            yb = _dispatch_compute_combine(
                xb.reshape(bl * sl, D), gb.reshape(bl * sl, -1),
                ib.reshape(bl * sl, -1), wg, wu, wd,
                shard_index=m, n_shards=m_size, capacity=cap)
            yb = yb.reshape(bl, sl, D)
            if ctx.model_axis:
                yb = jax.lax.psum(yb, ctx.model_axis)
            return yb

        from repro.parallel.context import shard_map_compat
        y = shard_map_compat(
            shard_fn, mesh=ctx.mesh,
            in_specs=(tok_spec, gate_spec, gate_spec, w_spec, w_spec, w_spec),
            out_specs=tok_spec,
        )(x, gates, idx, p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:
        y = y + L.mlp_forward(cfg, p["shared"], x)
    return y, aux * cfg.router_aux_loss
