"""Model assembly: one entry point for all 10 assigned architectures.

``build`` returns a family-appropriate ``ModelFns`` bundle of pure
functions (init / abstract init / logical specs / train forward / prefill
/ decode_step / cache_spec). Layer stacks run under jax.lax.scan with
stacked parameters so HLO size and compile time are O(1) in depth, and
remat ("full") wraps the scan body.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as PM
from repro.models.params import P, stacked
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import hybrid as HY


@dataclass
class CallOptions:
    remat: str = "none"             # none | full
    attn_chunk: int = 1024
    # applied to the residual stream between blocks (sequence parallelism /
    # sharding hints); signature x -> x
    act_constraint: Optional[Callable] = None
    # applied to logits (vocab sharding hint)
    logit_constraint: Optional[Callable] = None


def _maybe(fn, x):
    return fn(x) if fn is not None else x


# ---------------------------------------------------------------------------
# templates
# ---------------------------------------------------------------------------

def _ffn_template(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.n_experts:
        return MOE.moe_template(cfg)
    return L.mlp_template(cfg)


def _decoder_block_template(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": L.norm_template(cfg),
        "attn": L.attention_template(cfg),
        "ln2": L.norm_template(cfg),
        "ffn": _ffn_template(cfg),
    }


def param_template(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    t: Dict[str, Any] = {
        "tok_emb": P((v, d), ("vocab", "embed"), fan_in=d),
        "final_norm": L.norm_template(cfg),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = P((d, v), ("embed", "vocab"), fan_in=d)

    if cfg.family == "ssm":
        t["layers"] = stacked(cfg.n_layers, SSM.ssm_block_template(cfg))
    elif cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        n_groups, rem = divmod(cfg.n_layers, period)
        group_t = {}
        for i, kind in enumerate(cfg.block_pattern):
            group_t[f"b{i}_{kind}"] = (
                HY.rglru_block_template(cfg) if kind == "rglru"
                else HY.attn_block_template(cfg))
        t["groups"] = stacked(n_groups, group_t)
        for i in range(rem):
            kind = cfg.block_pattern[i]
            t[f"rem{i}_{kind}"] = (
                HY.rglru_block_template(cfg) if kind == "rglru"
                else HY.attn_block_template(cfg))
    elif cfg.is_encoder_decoder:
        t["enc_layers"] = stacked(cfg.n_encoder_layers, {
            "ln1": L.norm_template(cfg),
            "attn": L.attention_template(cfg),
            "ln2": L.norm_template(cfg),
            "ffn": L.mlp_template(cfg),
        })
        t["enc_final_norm"] = L.norm_template(cfg)
        t["layers"] = stacked(cfg.n_layers, {
            "ln1": L.norm_template(cfg),
            "attn": L.attention_template(cfg),
            "ln_cross": L.norm_template(cfg),
            "cross": L.attention_template(cfg),
            "ln2": L.norm_template(cfg),
            "ffn": L.mlp_template(cfg),
        })
    else:  # dense / moe / vlm decoder
        t["layers"] = stacked(cfg.n_layers, _decoder_block_template(cfg))
    return t


# ---------------------------------------------------------------------------
# block forward (dense/moe decoder)
# ---------------------------------------------------------------------------

def _decoder_block(cfg: ModelConfig, opts: CallOptions, p, x, positions,
                   cache=None):
    h = L.apply_norm(cfg, p["ln1"], x)
    a, new_kv = L.attention_forward(
        cfg, p["attn"], h, positions,
        window=cfg.attn_window, cache=cache, attn_chunk=opts.attn_chunk)
    x = x + a
    x = _maybe(opts.act_constraint, x)
    h = L.apply_norm(cfg, p["ln2"], x)
    if cfg.n_experts:
        f, aux = MOE.moe_forward(cfg, p["ffn"], h)
    else:
        f, aux = L.mlp_forward(cfg, p["ffn"], h), jnp.zeros((), jnp.float32)
    x = x + f
    x = _maybe(opts.act_constraint, x)
    return x, new_kv, aux


# ---------------------------------------------------------------------------
# backbone drivers (scan over stacked layers)
# ---------------------------------------------------------------------------

def _scan_decoder(cfg, opts, stacked_params, x, positions, caches):
    """caches: stacked pytree with leading layer dim, or None."""

    def body(carry, xs):
        xc, aux = carry
        p_l, cache_l = xs
        xc, new_kv, a = _decoder_block(cfg, opts, p_l, xc, positions, cache_l)
        return (xc, aux + a), new_kv

    body_fn = jax.checkpoint(body) if opts.remat == "full" else body
    (x, aux), new_caches = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), (stacked_params, caches))
    return x, aux, new_caches


def _scan_ssm(cfg, opts, stacked_params, x, caches):
    def body(carry, xs):
        xc = carry
        p_l, cache_l = xs
        out, new_c = SSM.ssm_block_forward(cfg, p_l, xc, cache_l)
        xc = _maybe(opts.act_constraint, xc + out)
        return xc, new_c

    body_fn = jax.checkpoint(body) if opts.remat == "full" else body
    x, new_caches = jax.lax.scan(body_fn, x, (stacked_params, caches))
    return x, new_caches


def _hybrid_group(cfg, opts, p_g, x, positions, cache_g):
    new_cache = {}
    for i, kind in enumerate(cfg.block_pattern):
        key = f"b{i}_{kind}"
        c = cache_g[key] if cache_g is not None else None
        if kind == "rglru":
            x, nc = HY.rglru_block_forward(cfg, p_g[key], x, c)
        else:
            x, nc = HY.attn_block_forward(cfg, p_g[key], x, positions, c)
        x = _maybe(opts.act_constraint, x)
        new_cache[key] = nc
    return x, new_cache


def _scan_hybrid(cfg, opts, params, x, positions, caches):
    def body(carry, xs):
        xc = carry
        p_g, cache_g = xs
        xc, new_c = _hybrid_group(cfg, opts, p_g, xc, positions, cache_g)
        return xc, new_c

    body_fn = jax.checkpoint(body) if opts.remat == "full" else body
    group_caches = caches["groups"] if caches is not None else None
    x, new_group_caches = jax.lax.scan(
        body_fn, x, (params["groups"], group_caches))

    period = len(cfg.block_pattern)
    rem = cfg.n_layers % period
    new_caches = {"groups": new_group_caches} if caches is not None else None
    for i in range(rem):
        kind = cfg.block_pattern[i]
        key = f"rem{i}_{kind}"
        c = caches[key] if caches is not None else None
        if kind == "rglru":
            x, nc = HY.rglru_block_forward(cfg, params[key], x, c)
        else:
            x, nc = HY.attn_block_forward(cfg, params[key], x, positions, c)
        if caches is not None:
            new_caches[key] = nc
    return x, new_caches


def _whisper_encoder(cfg, opts, params, frames):
    """frames: [B, Senc, D] precomputed embeddings (stub frontend)."""
    B, Se, D = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(xc, p_l):
        h = L.apply_norm(cfg, p_l["ln1"], xc)
        a, _ = L.attention_forward(cfg, p_l["attn"], h, pos, causal=False)
        xc = xc + a
        h = L.apply_norm(cfg, p_l["ln2"], xc)
        xc = xc + L.mlp_forward(cfg, p_l["ffn"], h)
        return xc, None

    body_fn = jax.checkpoint(body) if opts.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_final_norm"], x)


def _whisper_decoder(cfg, opts, params, x, positions, enc_out=None,
                     caches=None, cross_kv=None):
    """Either enc_out (train/prefill: compute cross k/v) or cross_kv
    (decode: precomputed, stacked over layers) must be given."""

    def body(carry, xs):
        xc = carry
        p_l, cache_l, ckv_l = xs
        h = L.apply_norm(cfg, p_l["ln1"], xc)
        a, new_kv = L.attention_forward(
            cfg, p_l["attn"], h, positions, cache=cache_l,
            attn_chunk=opts.attn_chunk)
        xc = xc + a
        h = L.apply_norm(cfg, p_l["ln_cross"], xc)
        if ckv_l is None:
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross"]["wv"])
        else:
            ck, cv = ckv_l
        c, _ = L.attention_forward(
            cfg, p_l["cross"], h, positions, cross_kv=(ck, cv),
            use_rope=False)
        xc = xc + c
        h = L.apply_norm(cfg, p_l["ln2"], xc)
        xc = xc + L.mlp_forward(cfg, p_l["ffn"], h)
        xc = _maybe(opts.act_constraint, xc)
        new_ckv = (ck, cv) if caches is not None else None
        return xc, (new_kv, new_ckv)

    body_fn = jax.checkpoint(body) if opts.remat == "full" else body
    self_caches = caches["self"] if caches is not None else None
    x, (new_self, new_cross) = jax.lax.scan(
        body_fn, x, (params["layers"], self_caches, cross_kv))
    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "cross": new_cross}
    return x, new_caches


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    return jnp.take(params["tok_emb"], tokens, axis=0)


def _logits(cfg, opts, params, x):
    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["tok_emb"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return _maybe(opts.logit_constraint, out)


def forward_train(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
                  opts: CallOptions = CallOptions()):
    """Full-sequence forward. batch: tokens [B,S] (+ frames for enc-dec).

    Returns (logits [B,S,V], aux: dict)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed(cfg, params, tokens)
    x = _maybe(opts.act_constraint, x)
    aux = {"moe_aux": jnp.zeros((), jnp.float32)}

    if cfg.family == "ssm":
        x, _ = _scan_ssm(cfg, opts, params["layers"], x, None)
    elif cfg.family == "hybrid":
        x, _ = _scan_hybrid(cfg, opts, params, x, positions, None)
    elif cfg.is_encoder_decoder:
        enc = _whisper_encoder(cfg, opts, params, batch["frames"])
        x, _ = _whisper_decoder(cfg, opts, params, x, positions, enc_out=enc,
                                cross_kv=None)
    else:
        x, moe_aux, _ = _scan_decoder(cfg, opts, params["layers"], x,
                                      positions, None)
        aux["moe_aux"] = moe_aux

    return _logits(cfg, opts, params, x), aux


# --- caches -----------------------------------------------------------------

def kv_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """Abstract spec for one attention layer's cache."""
    h, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    smax = min(max_seq, cfg.attn_window) if cfg.attn_window else max_seq
    bf16 = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((batch, smax, nkv, h), bf16),
        "v": jax.ShapeDtypeStruct((batch, smax, nkv, h), bf16),
        "pos": jax.ShapeDtypeStruct((batch, smax), jnp.int32),
    }


def _stack_spec(n: int, spec):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """Abstract cache pytree for the whole model (decode state)."""
    if cfg.family == "ssm":
        return _stack_spec(cfg.n_layers, SSM.ssm_cache_spec(cfg, batch))
    if cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        n_groups, rem = divmod(cfg.n_layers, period)
        g: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.block_pattern):
            g[f"b{i}_{kind}"] = (HY.rglru_cache_spec(cfg, batch)
                                 if kind == "rglru"
                                 else kv_cache_spec(cfg, batch, max_seq))
        out: Dict[str, Any] = {"groups": _stack_spec(n_groups, g)}
        for i in range(rem):
            kind = cfg.block_pattern[i]
            out[f"rem{i}_{kind}"] = (HY.rglru_cache_spec(cfg, batch)
                                     if kind == "rglru"
                                     else kv_cache_spec(cfg, batch, max_seq))
        return out
    if cfg.is_encoder_decoder:
        h, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
        bf16 = jnp.dtype(cfg.dtype)
        ck = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, nkv, h), bf16)
        return {
            "self": _stack_spec(cfg.n_layers, kv_cache_spec(cfg, batch, max_seq)),
            "cross": _stack_spec(cfg.n_layers, (ck, ck)),
        }
    return _stack_spec(cfg.n_layers, kv_cache_spec(cfg, batch, max_seq))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    spec = cache_spec(cfg, batch, max_seq)

    def zero(s):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(zero, spec)


# --- prefill / decode --------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, cache,
            opts: CallOptions = CallOptions(), frames=None):
    """Run the full prompt, filling `cache`. Returns (last_logits, cache)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed(cfg, params, tokens)
    x = _maybe(opts.act_constraint, x)

    if cfg.family == "ssm":
        x, new_cache = _scan_ssm(cfg, opts, params["layers"], x, cache)
    elif cfg.family == "hybrid":
        x, new_cache = _scan_hybrid(cfg, opts, params, x, positions, cache)
    elif cfg.is_encoder_decoder:
        enc = _whisper_encoder(cfg, opts, params, frames)
        x, new_cache = _whisper_decoder(cfg, opts, params, x, positions,
                                        enc_out=enc, caches=cache,
                                        cross_kv=None)
    else:
        x, _, new_cache = _scan_decoder(cfg, opts, params["layers"], x,
                                        positions, cache)

    logits = _logits(cfg, opts, params, x[:, -1:])
    return logits[:, 0], new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                opts: CallOptions = CallOptions()):
    """One token for every sequence. tokens: [B,1]; pos: [B] int32.

    Returns (logits [B,V], new_cache)."""
    B = tokens.shape[0]
    positions = pos[:, None].astype(jnp.int32)
    x = _embed(cfg, params, tokens)

    if cfg.family == "ssm":
        x, new_cache = _scan_ssm(cfg, opts, params["layers"], x, cache)
    elif cfg.family == "hybrid":
        x, new_cache = _scan_hybrid(cfg, opts, params, x, positions, cache)
    elif cfg.is_encoder_decoder:
        x, new_cache = _whisper_decoder(
            cfg, opts, params, x, positions,
            caches=cache, cross_kv=cache["cross"])
        new_cache = {"self": new_cache["self"], "cross": cache["cross"]}
    else:
        x, _, new_cache = _scan_decoder(cfg, opts, params["layers"], x,
                                        positions, cache)

    logits = _logits(cfg, opts, params, x)
    return logits[:, 0], new_cache


# --- init --------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng: jax.Array):
    return PM.init_concrete(param_template(cfg), cfg.dtype, rng)


def init_abstract(cfg: ModelConfig):
    return PM.init_abstract(param_template(cfg), cfg.dtype)


def logical_specs(cfg: ModelConfig):
    return PM.logical_specs(param_template(cfg))


def param_count(cfg: ModelConfig) -> int:
    return PM.count_params(init_abstract(cfg))
