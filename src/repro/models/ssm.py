"""Mamba-2 blocks: chunked SSD (state-space duality) scan.

Training/prefill uses the chunked SSD algorithm (quadratic within a
Q-token chunk, linear state passing across chunks) — the same blocking the
Pallas kernel (kernels/ssd_scan) implements on TPU. Decode is the O(1)
recurrent update. Head dim / state sizes follow arXiv:2405.21060.

Sharding: heads shard over the ``model`` axis; the (group-shared) B/C
projections and conv params are replicated (tiny).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import P
from repro.models.layers import norm_template, apply_norm, rmsnorm

CHUNK = 256


def dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state


def ssm_block_template(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_in, nh, ds = dims(cfg)
    hd = cfg.ssm_head_dim
    cw = cfg.ssm_conv_width
    return {
        "norm": norm_template(cfg),
        "w_z": P((d, nh, hd), ("embed", "heads", None), fan_in=d),
        "w_x": P((d, nh, hd), ("embed", "heads", None), fan_in=d),
        "w_B": P((d, ds), ("embed", None), fan_in=d),
        "w_C": P((d, ds), ("embed", None), fan_in=d),
        "w_dt": P((d, nh), ("embed", "heads"), fan_in=d),
        "conv_x": P((cw, nh, hd), (None, "heads", None), init="scaled", fan_in=cw),
        "conv_B": P((cw, ds), (None, None), init="scaled", fan_in=cw),
        "conv_C": P((cw, ds), (None, None), init="scaled", fan_in=cw),
        "conv_bx": P((nh, hd), ("heads", None), init="zeros"),
        "conv_bB": P((ds,), (None,), init="zeros"),
        "conv_bC": P((ds,), (None,), init="zeros"),
        "A_log": P((nh,), ("heads",), init="ssm_a", dtype="float32"),
        "D": P((nh,), ("heads",), init="ones", dtype="float32"),
        "dt_bias": P((nh,), ("heads",), init="ssm_dt", dtype="float32"),
        "out_norm": P((nh, hd), ("heads", None), init="zeros", dtype="float32"),
        "w_out": P((nh, hd, d), ("heads", None, "embed"), fan_in=d_in),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv via shifted adds. x: [B,S,...ch]; w: [cw,...ch].

    Returns (y, new_state) where state is the trailing cw-1 inputs."""
    cw = w.shape[0]
    if state is None:
        pad = [(0, 0)] * x.ndim
        pad[1] = (cw - 1, 0)
        xp = jnp.pad(x, pad)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, i:i + S] * w[i] for i in range(cw))
    new_state = xp[:, xp.shape[1] - (cw - 1):]
    return jax.nn.silu(y + b), new_state


def _segsum(dA: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j<t<=i} dA[..., t] (causal).

    dA: [..., Q]; returns [..., Q, Q] with -inf above the diagonal."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    ii, jj = jnp.meshgrid(jnp.arange(q), jnp.arange(q), indexing="ij")
    return jnp.where(ii >= jj, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array,
                chunk: int = CHUNK,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD. x:[B,S,H,P] dt:[B,S,H] A:[H] Bm/Cm:[B,S,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N]). f32 math."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    q = min(chunk, S)
    assert S % q == 0, (S, q)
    nc = S // q

    # chunk-major layout for the scan: [nc, B, q, ...]
    xf = x.astype(jnp.float32).reshape(Bsz, nc, q, H, Pd).transpose(1, 0, 2, 3, 4)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, q, H).transpose(1, 0, 2, 3)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, q, N).transpose(1, 0, 2, 3)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, q, N).transpose(1, 0, 2, 3)
    Af = A.astype(jnp.float32)

    def scan_fn(s_prev, inp):
        # ALL per-chunk work lives inside the scan body — the same
        # blocking as kernels/ssd_scan, so (a) the O(q^2) intra tiles
        # never exist for more than one chunk at a time and (b) the HLO
        # analyzer's innermost-loop kernel adjustment applies (this loop
        # IS the Pallas kernel on TPU).
        xc, dtc, bc, cc = inp                       # [B,q,H,Pd] etc.
        dA = dtc * Af                               # [B,q,H]
        cum = jnp.cumsum(dA, axis=1)
        xdt = xc * dtc[..., None]

        seg = cum.transpose(0, 2, 1)                # [B,H,q]
        diff = seg[..., :, None] - seg[..., None, :]
        ii = jnp.arange(q)
        causal = ii[:, None] >= ii[None, :]
        L = jnp.where(causal, jnp.exp(diff), 0.0)   # [B,H,q,q]
        scores = jnp.einsum("bin,bjn->bij", cc, bc)  # [B,q,q]
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores[:, None] * L, xdt)

        in_decay = jnp.exp(cum)                     # [B,q,H]
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cc, s_prev, in_decay)

        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)
        st = jnp.einsum("bqn,bqhp,bqh->bhpn", bc, xdt, decay_to_end)
        s_new = s_prev * jnp.exp(cum[:, -1, :])[:, :, None, None] + st
        return s_new, y_intra + y_inter

    s0 = (jnp.zeros((Bsz, H, Pd, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    s_final, ys = jax.lax.scan(scan_fn, s0, (xf, dtf, Bf, Cf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, Pd)
    return y, s_final


def ssd_decode_step(x, dt, A, Bm, Cm, state):
    """One-token recurrence. x:[B,1,H,P] dt:[B,1,H] Bm/Cm:[B,1,N]
    state:[B,H,P,N] -> (y [B,1,H,P], new_state)."""
    xf = x.astype(jnp.float32)[:, 0]
    dtf = dt.astype(jnp.float32)[:, 0]
    Bf = Bm.astype(jnp.float32)[:, 0]
    Cf = Cm.astype(jnp.float32)[:, 0]
    dec = jnp.exp(dtf * A.astype(jnp.float32))       # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", xf * dtf[..., None], Bf)
    s_new = state.astype(jnp.float32) * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, Cf)
    return y[:, None], s_new


def ssm_block_forward(
    cfg: ModelConfig,
    p: Dict[str, Any],
    x: jax.Array,                     # [B,S,D]
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 block (pre-norm, residual added by caller)."""
    B, S, D = x.shape
    d_in, nh, ds = dims(cfg)
    hd = cfg.ssm_head_dim
    h = apply_norm(cfg, p["norm"], x)

    z = jnp.einsum("bsd,dhp->bshp", h, p["w_z"])
    xs = jnp.einsum("bsd,dhp->bshp", h, p["w_x"])
    Bm = jnp.einsum("bsd,dn->bsn", h, p["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, p["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", h, p["w_dt"])

    cs_x = cache["conv_x"] if cache is not None else None
    cs_B = cache["conv_B"] if cache is not None else None
    cs_C = cache["conv_C"] if cache is not None else None
    xs, ns_x = _causal_conv(xs, p["conv_x"], p["conv_bx"], cs_x)
    Bm, ns_B = _causal_conv(Bm, p["conv_B"], p["conv_bB"], cs_B)
    Cm, ns_C = _causal_conv(Cm, p["conv_C"], p["conv_bC"], cs_C)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is not None and S == 1:
        y, s_new = ssd_decode_step(xs, dt, A, Bm, Cm, cache["state"])
    else:
        init = cache["state"] if cache is not None else None
        chunk = CHUNK if S % CHUNK == 0 else S
        y, s_new = ssd_chunked(xs, dt, A, Bm, Cm, chunk=chunk, init_state=init)

    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    # gated RMSNorm (per-head scale), then output projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + p["out_norm"])
    out = jnp.einsum("bshp,hpd->bsd", y.astype(x.dtype), p["w_out"])

    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": ns_x, "conv_B": ns_B, "conv_C": ns_C,
                     "state": s_new}
    return out, new_cache


def ssm_cache_spec(cfg: ModelConfig, batch: int):
    """Abstract cache entry for one SSM block."""
    d_in, nh, ds = dims(cfg)
    hd = cfg.ssm_head_dim
    cw = cfg.ssm_conv_width
    f32, bf16 = jnp.float32, jnp.dtype(cfg.dtype)
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, cw - 1, nh, hd), bf16),
        "conv_B": jax.ShapeDtypeStruct((batch, cw - 1, ds), bf16),
        "conv_C": jax.ShapeDtypeStruct((batch, cw - 1, ds), bf16),
        "state": jax.ShapeDtypeStruct((batch, nh, hd, ds), f32),
    }
