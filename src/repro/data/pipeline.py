"""Deterministic, seekable, checkpointable data pipeline.

The pipeline's *logical position* is a single integer cursor (global batch
index) — upper-half state. Batches are generated content-addressed from
(seed, cursor, shard): a counter-based Philox PRNG gives O(1) seek, so
restore fast-forwards by just setting the cursor (no replaying gigabytes
of input), and straggler-driven shard reassignment (DataReassign op)
changes only *which host materializes which rows*, never the bytes.

This stands in for a real corpus reader; the interface (batch_at /
host_slice / cursor) is what the C/R layer needs, and a file-backed
implementation would keep it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_size: int = 32000
    seq_len: int = 128
    global_batch: int = 8
    n_shards: int = 1            # host-level shards of the batch
    frames: int = 0              # >0: also emit encoder frames (enc-dec)
    frame_dim: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig,
                 assignment: Optional[List[Tuple[int, int]]] = None) -> None:
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        # host -> owned shards (straggler rebalancing rewrites this)
        self.assignment = assignment or [(h, h) for h in range(cfg.n_shards)]

    # --- deterministic generation ---------------------------------------

    def _rng(self, cursor: int, shard: int) -> np.random.Generator:
        bits = np.random.Philox(key=self.cfg.seed,
                                counter=[0, 0, cursor, shard])
        return np.random.Generator(bits)

    def _shard_batch(self, cursor: int, shard: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rows = c.global_batch // c.n_shards
        rng = self._rng(cursor, shard)
        # documents: zipf-ish token stream with eos resets (deterministic)
        toks = rng.integers(0, c.vocab_size, size=(rows, c.seq_len + 1),
                            dtype=np.int64).astype(np.int32)
        out = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }
        if c.frames:
            out["frames"] = rng.standard_normal(
                (rows, c.frames, c.frame_dim), dtype=np.float32)
        return out

    # --- public API -------------------------------------------------------

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        """Full global batch (single-controller path)."""
        shards = [self._shard_batch(cursor, s)
                  for s in range(self.cfg.n_shards)]
        return {k: np.concatenate([s[k] for s in shards], axis=0)
                for k in shards[0]}

    def host_slice(self, cursor: int, host: int) -> Dict[str, np.ndarray]:
        """Rows this host materializes under the current assignment."""
        owned = sorted(s for h, s in self.assignment if h == host)
        shards = [self._shard_batch(cursor, s) for s in owned]
        if not shards:
            return {}
        return {k: np.concatenate([s[k] for s in shards], axis=0)
                for k in shards[0]}

    def reassign(self, assignment: List[Tuple[int, int]]) -> None:
        self.assignment = list(assignment)

    def spec(self) -> Dict[str, tuple]:
        c = self.cfg
        out = {"tokens": ((c.global_batch, c.seq_len), np.int32),
               "targets": ((c.global_batch, c.seq_len), np.int32)}
        if c.frames:
            out["frames"] = ((c.global_batch, c.frames, c.frame_dim),
                             np.float32)
        return out
